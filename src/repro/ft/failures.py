"""Reliability & fault tolerance (paper §4).

* **Soft node failure** — a node keeps running but produces local NaNs;
  undetected, NaN weights contaminate checkpoints. ``NaNMonitor`` checks
  per-rank loss/grad-norm each step, identifies the offending rank, and
  raises ``NodeFailure(kind='soft')`` so the launcher can replace the node
  and relaunch from the last valid checkpoint.
* **Hard node failure** — the run dies outright (ping failure, segfault,
  OS error). ``ClusterManager`` models the paper's buffer-node scheme: a run
  is launched on ``n_active`` of ``n_active + n_buffer`` nodes; on failure
  the failed node is swapped for a buffer node and the run restarts.
* ``run_with_failure_handling`` is the launcher loop tying both to the dual
  checkpointer: fail -> swap node -> restore newest valid checkpoint ->
  continue. (This container has one host, so nodes are simulated objects —
  the control flow is the deliverable.)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


class NodeFailure(RuntimeError):
    def __init__(self, node_id: int, kind: str):
        super().__init__(f"{kind} failure on node {node_id}")
        self.node_id = node_id
        self.kind = kind


class NaNMonitor:
    """Per-rank NaN detection on loss and gradient norms (soft failures)."""

    def __init__(self, rank_of_value: Optional[Callable[[int], int]] = None):
        self.rank_of_value = rank_of_value or (lambda i: i)

    def check(self, per_rank_losses, per_rank_grad_norms=None, step: int = -1):
        losses = np.asarray(per_rank_losses)
        bad = ~np.isfinite(losses)
        if per_rank_grad_norms is not None:
            bad |= ~np.isfinite(np.asarray(per_rank_grad_norms))
        if bad.any():
            rank = int(np.argmax(bad))
            raise NodeFailure(self.rank_of_value(rank), "soft")


@dataclass
class Node:
    node_id: int
    healthy: bool = True


@dataclass
class ClusterManager:
    """Buffer-node bookkeeping (paper: 'launching the training run with some
    extra buffer nodes and ... replacing the failed node')."""
    n_active: int
    n_buffer: int
    active: list = field(default_factory=list)
    buffers: list = field(default_factory=list)
    replaced: list = field(default_factory=list)

    def __post_init__(self):
        if not self.active:
            self.active = [Node(i) for i in range(self.n_active)]
            self.buffers = [Node(self.n_active + i)
                            for i in range(self.n_buffer)]

    def replace(self, node_id: int) -> Node:
        if not self.buffers:
            raise RuntimeError("no buffer nodes left — cannot recover")
        idx = next(i for i, n in enumerate(self.active)
                   if n.node_id == node_id)
        failed = self.active[idx]
        failed.healthy = False
        repl = self.buffers.pop(0)
        self.active[idx] = repl
        self.replaced.append((failed.node_id, repl.node_id))
        return repl


def run_with_failure_handling(train_one_step, *, state, checkpointer,
                              cluster: ClusterManager, num_steps: int,
                              monitor: Optional[NaNMonitor] = None,
                              max_relaunches: int = 8,
                              on_relaunch=None, start_step: int = 0):
    """Launcher loop: step -> checkpoint -> on failure swap node + restore.

    ``train_one_step(state, step) -> (state, metrics)`` may raise
    NodeFailure (hard) or return NaN metrics (soft, caught by the monitor).
    ``start_step`` supports resuming a run already restored by the caller.
    Returns (state, step_reached, relaunches).
    """
    monitor = monitor or NaNMonitor()
    initial_state = state        # fallback when no valid checkpoint exists:
    relaunches = 0               # restart must NOT keep partial updates, or
    step = start_step            # replayed steps would be double-applied
    while step < num_steps:
        try:
            state, metrics = train_one_step(state, step)
            losses = metrics.get("per_rank_losses",
                                 [float(metrics.get("loss", 0.0))])
            monitor.check(losses, metrics.get("per_rank_grad_norms"),
                          step=step)
            checkpointer.maybe_save(state, getattr(state, "params", state),
                                    step)
            step += 1
        except NodeFailure as f:
            relaunches += 1
            if relaunches > max_relaunches:
                raise
            cluster.replace(f.node_id)
            restored, ck_step = checkpointer.restore(state)
            if restored is not None:
                state, step = restored, ck_step + 1  # post-step checkpoint
            else:
                state, step = initial_state, start_step
            if on_relaunch is not None:
                state = on_relaunch(state, f, step)
    return state, step, relaunches
