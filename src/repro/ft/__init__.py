from .failures import (NaNMonitor, NodeFailure, ClusterManager,
                       run_with_failure_handling)
