"""Continuous-batching serve engine.

One ``ServeEngine`` owns a model's params, a ``SlotKVPool`` and a
``FIFOScheduler``, and advances the whole request population one token per
``step()``:

  admit    scheduler pass (FIFO + prefill-priority, token-budgeted) claims a
           free cache slot per admitted request;
  prefill  the prompt is run through ``models.prefill_with_cache``, K/V land
           directly in the claimed slot and the *first* generated token is
           sampled from the last-position logits — the request joins the
           very next decode step;
  decode   ONE jitted ``decode_step`` over the full slot batch with a (B,)
           per-slot position vector — shapes never change, so the step
           compiles exactly once no matter how requests churn;
  evict    EOS / max-token rows free their slot for the next admission pass.

Host/device split: request bookkeeping (positions, generated tokens, free
slots) is host-side python; only the cache pytree and the per-step token
batch live on device. Steady-state decode costs one device sync per step
(the ``np.asarray(next_tokens)`` after decode); each admitted request adds
one more for its prefill's first token.

``make_decode_fn`` / ``make_prefill_fn`` are the engine's two lowerings and
are also what ``train.trainer.make_serve_step`` / ``make_prefill_step``
build on — the dry-run's decode_32k / long_500k shapes and the engine share
one code path.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, prefill_with_cache
from repro.parallel.plan import use_kernel_plan
from .kv_pool import SlotKVPool
from .sampling import SamplingParams, position_keys, sample_tokens
from .scheduler import FIFOScheduler, Request


def dropless_cfg(cfg: ModelConfig) -> ModelConfig:
    """Serving must be batching-transparent: with a capacity-limited MoE
    (cf < E/K), whether a token's expert contribution is dropped depends on
    which other rows share the batch — a request's output would change with
    batch composition. Raise the capacity factor to the dropless bound for
    the serve lowerings (decode batches are small; the extra pool rows are
    noise next to the KV cache). A ``dispatch='dropless'`` config is already
    batching-transparent by construction — its pool is sized for the
    worst-case routing at any capacity_factor — so it passes through."""
    if not cfg.is_moe:
        return cfg
    m = cfg.moe
    if m.dispatch == "dropless":
        return cfg
    need = m.num_experts / max(m.experts_per_token, 1)
    if m.capacity_factor >= need:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(m, capacity_factor=float(need)))


def make_decode_fn(cfg: ModelConfig, *, rules=None,
                   compute_dtype=jnp.float32, kernel_plan=None):
    """Build the engine's decode lowering: one token for every slot, sampled
    with per-slot params. All arguments are (B,)-shaped except tokens (B, 1)
    — jit once, reuse forever. ``kernel_plan`` (a plan's KernelPlan) is
    scoped over the trace."""
    cfg = dropless_cfg(cfg)
    vocab = cfg.vocab_size

    def decode_fn(params, tokens, cache, positions, seeds,
                  temperature, top_k, top_p):
        with use_kernel_plan(kernel_plan):
            logits, cache = decode_step(params, tokens, cache, positions,
                                        cfg, rules=rules,
                                        compute_dtype=compute_dtype)
            keys = position_keys(seeds, positions)
            nxt = sample_tokens(logits[:, 0, :vocab], keys, temperature,
                                top_k, top_p)
            return nxt, cache

    return decode_fn


def make_prefill_fn(cfg: ModelConfig, *, rules=None, mesh=None,
                    compute_dtype=jnp.float32, kernel_plan=None):
    """Build the engine's prefill lowering: write prompt K/V into cache rows
    and sample the first generated token from the last-position logits
    (keyed on position length-1, so single-request replay matches)."""
    cfg = dropless_cfg(cfg)
    vocab = cfg.vocab_size

    def prefill_fn(params, tokens, cache, slots, lengths, seeds,
                   temperature, top_k, top_p):
        with use_kernel_plan(kernel_plan):
            last, cache = prefill_with_cache(params, tokens, cache, slots,
                                             lengths, cfg, rules=rules,
                                             mesh=mesh,
                                             compute_dtype=compute_dtype)
            keys = position_keys(seeds, lengths - 1)
            first = sample_tokens(last[:, :vocab], keys, temperature,
                                  top_k, top_p)
            return first, cache

    return prefill_fn


@dataclass
class GenResult:
    rid: int
    prompt_len: int
    tokens: list[int]
    finish_reason: str                   # 'eos' | 'length'
    arrival_time: float = 0.0
    token_times: list[float] = field(default_factory=list)


@dataclass
class _SlotState:
    req: Request
    slot: int
    pos: int                             # position the next token is fed at
    tokens: list[int] = field(default_factory=list)
    token_times: list[float] = field(default_factory=list)


def _bucket(n: int, floor: int) -> int:
    """Next power of two >= max(n, floor) — prefill retraces per bucket, not
    per prompt length."""
    b = floor
    while b < n:
        b *= 2
    return b


class ServeEngine:
    """See module docstring. ``num_slots`` bounds concurrent requests;
    ``max_len`` sizes full caches (ring configs are O(window) regardless).
    ``eos_id=None`` disables EOS termination (smoke models emit arbitrary
    ids)."""

    def __init__(self, params, cfg: ModelConfig, *, num_slots: int = 8,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 scheduler: Optional[FIFOScheduler] = None,
                 cache_dtype=jnp.float32, compute_dtype=jnp.float32,
                 plan=None, rules=None, mesh=None, prefill_bucket: int = 8,
                 decode_fn=None, prefill_fn=None):
        if cfg.arch_type not in ("dense", "moe"):
            raise NotImplementedError(
                "ServeEngine drives attention-KV archs (dense, moe); "
                f"got {cfg.arch_type!r}")
        if plan is not None:      # a ResolvedPlan supplies the placement
            rules = rules if rules is not None else plan.rules
            mesh = mesh if mesh is not None else plan.mesh
        kernel_plan = plan.kernel if plan is not None else None
        self.params = params
        self.cfg = cfg
        self.plan = plan
        self.eos_id = eos_id
        self.pool = SlotKVPool(cfg, num_slots, max_len, cache_dtype)
        self.scheduler = scheduler or FIFOScheduler()
        self.prefill_bucket = prefill_bucket
        # decode_fn/prefill_fn: already-jitted lowerings to share a compile
        # cache across engines (benchmarks spin up several engines over the
        # same config — recompiling per engine would swamp the clock)
        self._decode = decode_fn or jax.jit(
            make_decode_fn(cfg, rules=rules, compute_dtype=compute_dtype,
                           kernel_plan=kernel_plan))
        self._prefill = prefill_fn or jax.jit(
            make_prefill_fn(cfg, rules=rules, mesh=mesh,
                            compute_dtype=compute_dtype,
                            kernel_plan=kernel_plan))
        self._slots: dict[int, _SlotState] = {}
        self._results: dict[int, GenResult] = {}
        self._next_rid = 0
        self.steps = 0
        self.tokens_generated = 0

    # ---- request intake -----------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 32,
               sampling: Optional[SamplingParams] = None,
               arrival_time: float = 0.0) -> int:
        sampling = sampling if sampling is not None else SamplingParams()
        if len(prompt) == 0:
            raise ValueError("empty prompt: the first token is sampled from "
                             "the last prompt position, so one is required")
        if self.cfg.sliding_window <= 0 and \
                len(prompt) + max_new_tokens > self.pool.max_len:
            raise ValueError(
                f"prompt+generation ({len(prompt)}+{max_new_tokens}) "
                f"exceeds cache max_len {self.pool.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        self.scheduler.submit(Request(rid, list(prompt), max_new_tokens,
                                      sampling, arrival_time))
        return rid

    # ---- one engine step ----------------------------------------------------
    def step(self, now: Optional[float] = None) -> list[GenResult]:
        """Admit + prefill newcomers, then decode one token for every
        in-flight request. Returns the requests that finished this step."""
        finished: list[GenResult] = []

        # admissions prefill one request per call (B'=1): batching them
        # would retrace the jitted prefill per (bucket, group-size) pair,
        # which costs more than the k-1 dispatches it saves
        for req in self.scheduler.pop_admissible(self.pool.num_free, now):
            slot = self.pool.alloc()
            L = req.prompt_len
            P = _bucket(L, self.prefill_bucket)
            toks = np.zeros((1, P), np.int32)
            toks[0, :L] = req.prompt
            sp = req.sampling
            first, self.pool.cache = self._prefill(
                self.params, jnp.asarray(toks), self.pool.cache,
                jnp.asarray([slot], jnp.int32), jnp.asarray([L], jnp.int32),
                jnp.asarray([sp.seed], jnp.int32),
                jnp.asarray([sp.temperature], jnp.float32),
                jnp.asarray([sp.top_k], jnp.int32),
                jnp.asarray([sp.top_p], jnp.float32))
            st = _SlotState(req=req, slot=slot, pos=L)
            self._slots[slot] = st
            self._emit(st, int(first[0]), finished)

        if self._slots:
            B = self.pool.num_slots
            tokens = np.zeros((B, 1), np.int32)
            positions = np.zeros((B,), np.int32)
            seeds = np.zeros((B,), np.int32)
            temperature = np.zeros((B,), np.float32)
            top_k = np.zeros((B,), np.int32)
            top_p = np.ones((B,), np.float32)
            for slot, st in self._slots.items():
                sp = st.req.sampling
                tokens[slot, 0] = st.tokens[-1]
                positions[slot] = st.pos
                seeds[slot] = sp.seed
                temperature[slot] = sp.temperature
                top_k[slot] = sp.top_k
                top_p[slot] = sp.top_p
            nxt, self.pool.cache = self._decode(
                self.params, jnp.asarray(tokens), self.pool.cache,
                jnp.asarray(positions), jnp.asarray(seeds),
                jnp.asarray(temperature), jnp.asarray(top_k),
                jnp.asarray(top_p))
            nxt = np.asarray(nxt)                    # the one device sync
            for slot, st in list(self._slots.items()):
                st.pos += 1
                self._emit(st, int(nxt[slot]), finished)

        self.steps += 1
        return finished

    def _emit(self, st: _SlotState, token: int,
              finished: list[GenResult]) -> None:
        """Append one generated token; finish/evict on EOS or length."""
        if self.eos_id is not None and token == self.eos_id:
            self._finish(st, "eos", finished)
            return
        st.tokens.append(token)
        st.token_times.append(time.perf_counter())
        self.tokens_generated += 1
        if len(st.tokens) >= st.req.max_new_tokens:
            self._finish(st, "length", finished)

    def _finish(self, st: _SlotState, reason: str,
                finished: list[GenResult]) -> None:
        res = GenResult(st.req.rid, st.req.prompt_len, st.tokens, reason,
                        arrival_time=st.req.arrival_time,
                        token_times=st.token_times)
        self._results[st.req.rid] = res
        finished.append(res)
        del self._slots[st.slot]
        self.pool.free(st.slot)

    # ---- drive to completion -------------------------------------------------
    @property
    def active(self) -> int:
        return len(self._slots)

    @property
    def results(self) -> dict[int, GenResult]:
        """Finished requests so far, keyed by rid."""
        return self._results

    def run(self) -> dict[int, GenResult]:
        """Step until the queue and all slots drain (ignores arrival times —
        trace replay drives ``step(now=...)`` itself, see bench_serve.py)."""
        while len(self.scheduler) or self._slots:
            self.step()
        return self._results
