"""Slot-indexed KV/SSM cache pool for continuous batching.

One device-resident cache pytree (built by ``models.init_cache``) whose
batch axis is reinterpreted as *slots*: every leaf is (L, num_slots, ...)
with the slot axis at position 1, so a single jitted ``decode_step`` over
the full slot batch serves a churning request population without
recompilation — requests come and go, the arrays never change shape.

Slot bookkeeping (free list) lives on the host; slot *contents* need no
eager cleanup because the decode path masks cache entries by the per-slot
position vector (a freed slot's stale K/V is unreachable from any validity
mask — tests/test_serve.py::test_slot_reuse_no_leakage). ``reset_slot`` is
still provided as a debugging / hygiene aid. Sliding-window configs get
O(window) ring-buffer slots instead of O(max_len) rows — the long_500k
lowering.
"""
from __future__ import annotations

from collections import deque

import jax
import jax.numpy as jnp

from repro.models import init_cache


class SlotKVPool:
    """Fixed-capacity pool of cache slots over ``models.init_cache``."""

    def __init__(self, cfg, num_slots: int, max_len: int,
                 dtype=jnp.float32):
        if cfg.arch_type == "audio":
            raise NotImplementedError(
                "audio caches carry a (B, S, d) encoder memory leaf; the "
                "slot pool assumes a leading (layer, slot) layout")
        if 0 < max_len < cfg.sliding_window:
            # a ring smaller than the model's window silently narrows
            # attention from the second decode token onward (prefill attends
            # with the full window; the truncated ring can't store it)
            raise ValueError(
                f"max_len {max_len} < sliding_window {cfg.sliding_window}: "
                "ring slots must hold the model's full attention window")
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.cache = init_cache(cfg, num_slots, max_len, dtype)
        # deque carries the reuse ORDER; the mirror set makes the free()
        # double-free check O(1) instead of an O(n) deque membership scan
        # (it was the per-request hot path at high slot counts)
        self._free = deque(range(num_slots))
        self._free_set = set(self._free)

    # ---- host-side bookkeeping ---------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        """Claim a free slot (front of the free deque — most-recently-freed
        first, else lowest index — keeping reuse patterns deterministic for
        tests). Raises when the pool is exhausted — admission control must
        check ``num_free`` first. O(1)."""
        if not self._free:
            raise RuntimeError("KV pool exhausted: no free slots")
        slot = self._free.popleft()
        self._free_set.discard(slot)
        return slot

    def free(self, slot: int) -> None:
        """Return a slot to the pool; double-frees and out-of-range slots
        raise. O(1)."""
        if slot in self._free_set or not 0 <= slot < self.num_slots:
            raise ValueError(f"bad free of slot {slot}")
        self._free.appendleft(slot)
        self._free_set.add(slot)

    # ---- device-side content -----------------------------------------------
    def reset_slot(self, slot: int) -> None:
        """Zero one slot row in every leaf (not required for correctness —
        see module docstring — but useful when hunting leakage)."""
        self.cache = jax.tree.map(lambda a: a.at[:, slot].set(0), self.cache)

    def slot_bytes(self) -> int:
        """Per-slot cache footprint (capacity planning / admission knobs)."""
        return sum(a.nbytes // self.num_slots
                   for a in jax.tree.leaves(self.cache))
