"""Request admission: FIFO with prefill-priority and a token budget.

The engine runs one scheduler pass per step, *before* the batched decode
(prefill-priority: a newly arrived request is prefilled and joins the very
next decode step rather than waiting for the batch to drain — the
continuous-batching property). Admission is FIFO-ordered and bounded by

  * free cache slots (capacity), and
  * ``prefill_token_budget`` — max prompt tokens prefilled per engine step.
    Prefill of admitted requests runs between two decode steps, so this knob
    caps the per-token latency spike the in-flight requests see when a burst
    arrives (the analog of rtp-llm's max_context_batch_size).

A head-of-line request longer than the whole budget is admitted alone
rather than starved.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional, Sequence

from .sampling import SamplingParams


@dataclass
class Request:
    rid: int
    prompt: Sequence[int]
    max_new_tokens: int = 32
    sampling: SamplingParams = field(default_factory=SamplingParams)
    arrival_time: float = 0.0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


class FIFOScheduler:
    def __init__(self, prefill_token_budget: int = 2048):
        self.prefill_token_budget = prefill_token_budget
        self._queue: deque[Request] = deque()

    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def __len__(self) -> int:
        return len(self._queue)

    def pop_admissible(self, free_slots: int,
                       now: Optional[float] = None) -> list[Request]:
        """Admit FIFO-head requests while slots and the token budget last.
        ``now`` (wall-clock) gates requests whose ``arrival_time`` lies in
        the future — lets benchmarks replay a recorded arrival trace."""
        admitted: list[Request] = []
        budget = self.prefill_token_budget
        while self._queue and free_slots > 0:
            head = self._queue[0]
            if now is not None and head.arrival_time > now:
                break
            if admitted and head.prompt_len > budget:
                break                      # keep for next step; no starvation
            admitted.append(self._queue.popleft())
            free_slots -= 1
            budget -= head.prompt_len
        return admitted
