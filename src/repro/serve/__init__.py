"""Continuous-batching serving engine (the ROADMAP "serve heavy traffic"
subsystem; request lifecycle documented in docs/ARCHITECTURE.md).

    SamplingParams / sample_tokens   per-request sampling   (sampling.py)
    SlotKVPool                       slot-indexed cache     (kv_pool.py)
    Request / FIFOScheduler          admission control      (scheduler.py)
    ServeEngine / GenResult          the engine             (engine.py)
"""
from .sampling import SamplingParams, sample_tokens
from .kv_pool import SlotKVPool
from .scheduler import Request, FIFOScheduler
from .engine import ServeEngine, GenResult, make_decode_fn, make_prefill_fn
