"""Per-request token sampling for the serve engine.

Every request carries its own ``SamplingParams``; the engine packs them into
per-slot arrays so one jitted ``sample_tokens`` serves a batch that mixes
greedy, temperature, top-k and nucleus requests without recompilation.

Determinism contract (tested in tests/test_serve.py): the token sampled for
request *r* at absolute position *p* depends only on (r.seed, p) and the
logits — never on which slot the request occupies or who else is in the
batch. The engine derives the per-slot key as
``fold_in(PRNGKey(seed), position)``, so evicting and readmitting a request
(or replaying it alone) reproduces the same tokens.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    """Per-request knobs. ``temperature <= 0`` means greedy argmax (top-k /
    top-p are then irrelevant); ``top_k == 0`` disables top-k; ``top_p >= 1``
    disables nucleus filtering."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0


def position_keys(seeds: jax.Array, positions: jax.Array) -> jax.Array:
    """(B,) seeds x (B,) positions -> (B, 2) uint32 PRNG keys, one per slot."""
    return jax.vmap(lambda s, p: jax.random.fold_in(
        jax.random.PRNGKey(s), p))(seeds, positions)


def sample_tokens(logits: jax.Array, keys: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Sample one token per row with *per-row* parameters.

    logits: (B, V) — already sliced to the real vocab (no padding columns);
    keys: (B, 2) uint32; temperature/top_k/top_p: (B,). Rows with
    ``temperature <= 0`` take the argmax. Returns (B,) int32.

    top-k masks everything below the k-th logit; top-p keeps the smallest
    prefix of the (temperature-scaled, top-k-filtered) distribution whose
    mass reaches p — always at least the most likely token.
    """
    B, V = logits.shape
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1)

    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    order = jnp.argsort(-scaled, axis=-1)                  # descending
    ranks = jnp.argsort(order, axis=-1)                    # rank per column
    k = jnp.where(top_k > 0, top_k, V)[:, None]
    kept = jnp.where(ranks < k, scaled, -jnp.inf)

    sorted_kept = jnp.take_along_axis(kept, order, axis=-1)
    probs = jax.nn.softmax(sorted_kept, axis=-1)
    cdf_before = jnp.cumsum(probs, axis=-1) - probs        # exclusive cumsum
    keep_sorted = cdf_before < top_p[:, None]              # >= 1 column kept
    keep = jnp.zeros_like(keep_sorted).at[
        jnp.arange(B)[:, None], order].set(keep_sorted)
    final = jnp.where(keep, kept, -jnp.inf)

    sampled = jax.vmap(jax.random.categorical)(keys, final)
    return jnp.where(temperature <= 0, greedy, sampled).astype(jnp.int32)
