"""Offline data preprocessing (paper §4 "Data preprocessing"):

  1. **Tokenization** — each data file D_i becomes a token array T_i by
     tokenizing its documents and joining them with EOS.
  2. **Shuffling** — a permutation P over the N = Σ N_i training instances
     (N_i = len(T_i) // C for context size C), seeded and reproducible.
  3. **Sharding** — instances are gathered in permutation order and written
     to shard files loaded later in mmap mode; every DP rank then reads a
     *contiguous* region of one file (minimal token-consumption overhead).

Output layout:  out_dir/shard_{k:05d}.npy  (int32, [n_k, C])
                out_dir/meta.json          {context, num_instances, shards,...}
"""
from __future__ import annotations

import json
import os
from typing import Sequence

import numpy as np

from .tokenizer import ByteTokenizer


def tokenize_files(doc_files: Sequence[Sequence[str]], tokenizer=None):
    """Step 1: doc_files = list of 'data files', each a list of documents.
    Returns one token array per data file (documents joined by EOS)."""
    tok = tokenizer or ByteTokenizer()
    arrays = []
    for docs in doc_files:
        parts = []
        for doc in docs:
            parts.append(tok.encode(doc))
            parts.append(np.array([tok.EOS], np.int32))
        arrays.append(np.concatenate(parts) if parts
                      else np.zeros((0,), np.int32))
    return arrays


def preprocess_corpus(doc_files: Sequence[Sequence[str]], out_dir: str, *,
                      context: int = 256, shard_instances: int = 1024,
                      seed: int = 0, tokenizer=None) -> dict:
    """Full pipeline: tokenize -> shuffle -> shard. Returns the meta dict."""
    os.makedirs(out_dir, exist_ok=True)
    token_arrays = tokenize_files(doc_files, tokenizer)

    # instances per file: N_i = len(T_i) // (context+1) (inputs + next-token)
    step = context + 1
    instances = []
    for t in token_arrays:
        n = len(t) // step
        if n:
            instances.append(t[:n * step].reshape(n, step))
    if not instances:
        raise ValueError("corpus too small for one training instance")
    all_inst = np.concatenate(instances, axis=0)
    N = all_inst.shape[0]

    # step 2: permutation over all instances
    perm = np.random.default_rng(seed).permutation(N)
    all_inst = all_inst[perm]

    # step 3: shard files
    shards = []
    for k, start in enumerate(range(0, N, shard_instances)):
        path = os.path.join(out_dir, f"shard_{k:05d}.npy")
        np.save(path, all_inst[start:start + shard_instances])
        shards.append(os.path.basename(path))

    meta = {"context": context, "num_instances": int(N), "shards": shards,
            "seed": seed, "shard_instances": shard_instances,
            "vocab_size": (tokenizer or ByteTokenizer()).vocab_size}
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f)
    return meta
