"""Byte-level tokenizer (offline container — no external tokenizer deps).

Vocabulary: 256 byte values + special tokens. The data pipeline (paper §4)
is tokenizer-agnostic; swapping in a BPE tokenizer changes only this file.
"""
from __future__ import annotations

import numpy as np


class ByteTokenizer:
    EOS = 256
    PAD = 257
    VOCAB = 258

    @property
    def vocab_size(self) -> int:
        return self.VOCAB

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(
            np.int32)

    def decode(self, ids) -> str:
        ids = np.asarray(ids)
        ids = ids[(ids >= 0) & (ids < 256)]
        return bytes(ids.astype(np.uint8)).decode("utf-8", errors="replace")
