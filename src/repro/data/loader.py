"""mmap-mode shard loader (paper §4): lazy, contiguous per-DP-rank reads.

Global step b consumes instances [b*GB, (b+1)*GB); DP rank r with per-rank
batch size br reads the contiguous slice [b*GB + r*br, b*GB + (r+1)*br) —
one contiguous region of (at most two) shard files.
"""
from __future__ import annotations

import json
import os

import numpy as np


class ShardedDataLoader:
    def __init__(self, data_dir: str, *, global_batch: int,
                 dp_rank: int = 0, dp_size: int = 1, start_step: int = 0):
        with open(os.path.join(data_dir, "meta.json")) as f:
            self.meta = json.load(f)
        assert global_batch % dp_size == 0
        self.global_batch = global_batch
        self.rank_batch = global_batch // dp_size
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.start_step = start_step     # where __iter__ (re)starts
        self._mmaps = [np.load(os.path.join(data_dir, s), mmap_mode="r")
                       for s in self.meta["shards"]]
        self._sizes = np.array([m.shape[0] for m in self._mmaps])
        self._offsets = np.concatenate([[0], np.cumsum(self._sizes)])
        self.num_instances = int(self._offsets[-1])
        self.steps_per_epoch = self.num_instances // global_batch

    def _gather(self, start: int, count: int) -> np.ndarray:
        """Contiguous instance range across shard boundaries."""
        out = []
        while count > 0:
            k = int(np.searchsorted(self._offsets, start, side="right") - 1)
            local = start - int(self._offsets[k])
            take = min(count, int(self._sizes[k]) - local)
            out.append(np.asarray(self._mmaps[k][local:local + take]))
            start += take
            count -= take
        return np.concatenate(out, axis=0)

    def batch(self, step: int) -> dict:
        """(tokens, labels) for this DP rank at a global step (wraps per
        epoch). Shapes: (rank_batch, context)."""
        base = (step % self.steps_per_epoch) * self.global_batch
        start = base + self.dp_rank * self.rank_batch
        inst = self._gather(start, self.rank_batch).astype(np.int32)
        return {"tokens": inst[:, :-1], "labels": inst[:, 1:]}

    # ---- fault-tolerant resume ------------------------------------------
    # The batch sequence is a pure function of the global step, so resume
    # hygiene is just "restart the iterator at the restored step" — the
    # launcher restores a checkpoint at step k and points the loader at k+1,
    # replaying the exact batch order an uninterrupted run would have seen.
    # The loader is ONE resumable stream: ``start_step`` is a shared step
    # cursor that every iterator reads and advances on each next(), so
    # ``load_state_dict`` re-points live iterators mid-flight and
    # ``state_dict`` always names the next step to be served (a second
    # ``iter()`` continues the stream rather than restarting at 0).

    def state_dict(self) -> dict:
        """``step`` = the next global step the iterator will serve."""
        return {"step": self.start_step}

    def load_state_dict(self, state: dict) -> None:
        self.start_step = int(state["step"])

    def __iter__(self):
        while True:
            b = self.batch(self.start_step)
            self.start_step += 1
            yield b
