from .tokenizer import ByteTokenizer
from .preprocess import preprocess_corpus
from .loader import ShardedDataLoader
