"""Per-architecture sharding rules over named mesh axes.

Two mesh vocabularies feed the same ``ShardingRules`` engine:

* the legacy ('data','model') / ('pod','data','model') production mesh,
  where ``make_rules`` infers the 'model' axis's role per (arch, kind):
  'tp' (Megatron TP), 'ep' (paper §1 expert parallelism) or 'etp' (experts'
  d_ff sharded — the fallback when num_experts doesn't divide the axis);
* the ParallelPlan mesh (parallel/plan.py), where every axis is explicit —
  'data'/'pod' (DP), 'pp' (pipeline stages), and *separate* 'ep' and 'tp'
  axes. ``tp_axis`` and ``ep_axis`` may both be set: expert stacks shard
  over ep on the stacked-expert dim AND over tp on their d_ff dim
  (expert-TP — the Mula-100B/220B mesh shape role inference on one shared
  'model' axis could not express).

Whatever the vocabulary, tp_axis/ep_axis hold mesh-axis *names*; everything
below pattern-matches on those, so 'model' and 'ep'/'tp' behave identically.

Optimizer-state sharding (paper §3.2):
  * 'so'   — states sharded over DP only (the baseline Sharded Optimizer).
  * 'epso' — EP-Aware: states of model-axis-replicated params additionally
             sharded over the model-like axes (DP×EP-way).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    mesh: Optional[Mesh]
    batch_axes: tuple            # mesh axes sharding the batch/token dim
    tp_axis: Optional[str]       # 'model' (legacy role) or 'tp' (plan mesh)
    ep_axis: Optional[str]       # 'model' (legacy role) or 'ep' (plan mesh)
    fsdp: bool = False           # also shard params over data axes (ZeRO-3)
    pp_axis: Optional[str] = None  # 'pp' when pipeline stages are meshed
    cfg: object = None           # ModelConfig (for divisibility checks)

    # ---- helpers -----------------------------------------------------------
    def _axis_size(self, ax) -> int:
        if self.mesh is None:
            return 1
        if isinstance(ax, tuple):
            n = 1
            for a in ax:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[ax]

    def _div(self, dim: int, ax) -> bool:
        return ax is not None and dim % self._axis_size(ax) == 0

    def constrain(self, x, name: str):
        if self.mesh is None:
            return x
        spec = self.act_spec(name, x.shape)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def act_spec(self, name: str, shape) -> Optional[P]:
        b = tuple(self.batch_axes)
        batch = b if len(b) > 1 else (b[0] if b else None)
        tp = self.tp_axis
        if name == "act_btd":                       # (B,S,d) or (T,d)
            return P(*([batch] + [None] * (len(shape) - 1)))
        if name == "act_heads":                     # (B,S,H,hd)
            hs = tp if self._div(shape[-2], tp) else None
            return P(batch, None, hs, None)
        if name == "act_kv_heads":
            hs = tp if self._div(shape[-2], tp) else None
            return P(batch, None, hs, None)
        if name == "act_ff":                        # (B,S,f) or (T,f)
            fs = tp if self._div(shape[-1], tp) else None
            return P(*([batch] + [None] * (len(shape) - 2) + [fs]))
        if name == "logits":                        # (B,S,V)
            vs = tp if self._div(shape[-1], tp) else None
            return P(*([batch] + [None] * (len(shape) - 2) + [vs]))
        if name == "moe_pool":                      # (E, C, d)
            cs = batch if self._div_batch(shape[1]) else None
            return P(None, cs, None)
        if name == "moe_hidden":                    # (E, C, f)
            cs = batch if self._div_batch(shape[1]) else None
            fs = tp if self._div(shape[-1], tp) else None
            return P(None, cs, fs)
        return None

    def _div_batch(self, dim: int) -> bool:
        if not self.batch_axes:
            return False
        return dim % self._axis_size(tuple(self.batch_axes)) == 0


def resolve_batch_axes(global_batch: Optional[int], mesh: Mesh,
                       candidates: tuple) -> tuple:
    """Greedy: drop axes (from the left) until the batch divides the product.
    A batch too small for the full mesh stays replicated on the dropped axes
    (the dry-run reports the resulting waste honestly)."""
    if global_batch is None:
        return candidates
    axes = list(candidates)
    while axes:
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if global_batch % n == 0:
            return tuple(axes)
        axes.pop(0)
    return ()


def ep_batch_axes(mesh: Mesh, ep_axis: str, global_batch: Optional[int],
                  data_axes: Optional[tuple] = None) -> tuple:
    """Token/batch axes under EP: tokens span (pod, data, ep_axis) when the
    batch divides across them; otherwise fall back to the DP axes only and
    let the MoE block reshard tokens over the EP axis internally (shard_map
    in_specs). Shared by the legacy role inference and plan resolution so
    the two paths can never diverge."""
    if data_axes is None:
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    batch = resolve_batch_axes(global_batch, mesh, data_axes + (ep_axis,))
    if ep_axis not in batch:
        batch = resolve_batch_axes(global_batch, mesh, data_axes)
    return batch


def make_rules(cfg, mesh: Optional[Mesh], *, role: Optional[str] = None,
               kind: str = "train", fsdp: Optional[bool] = None,
               global_batch: Optional[int] = None) -> ShardingRules:
    """Resolve the model-axis role for (arch, input-shape-kind)."""
    if mesh is None:
        return ShardingRules(None, (), None, None, cfg=cfg)
    axes = list(mesh.shape.keys())
    data_axes = tuple(a for a in axes if a in ("pod", "data"))
    has_model = "model" in axes
    # a pp axis of size > 1 stage-shards the stacked layer dim (param_specs);
    # it never carries batch or tensor dims.
    pp = "pp" if ("pp" in axes and mesh.shape["pp"] > 1) else None

    if role is None:
        if cfg.is_moe:
            role = "ep" if kind == "train" else "etp"
        else:
            role = "tp"
    if role == "ep" and cfg.is_moe and has_model:
        ep_ok = cfg.moe.num_experts % mesh.shape["model"] == 0
        if not ep_ok:
            role = "etp"    # e.g. mixtral 8e on 16-way axis
    if role == "ep":
        batch = ep_batch_axes(mesh, "model", global_batch, data_axes) \
            if has_model else resolve_batch_axes(global_batch, mesh,
                                                 data_axes)
        return ShardingRules(mesh, batch, None, "model" if has_model else None,
                             fsdp=bool(fsdp), pp_axis=pp, cfg=cfg)
    batch = resolve_batch_axes(global_batch, mesh, data_axes)
    tp = "model" if has_model else None
    return ShardingRules(mesh, batch, tp, None, fsdp=bool(fsdp), pp_axis=pp,
                         cfg=cfg)


# ----------------------------------------------------------------------------
# parameter PartitionSpecs (pattern-matched on tree paths)
# ----------------------------------------------------------------------------

def _param_spec(path: str, shape, rules: ShardingRules) -> P:
    tp, ep = rules.tp_axis, rules.ep_axis
    mdl = tp or ep   # the model axis name if any role is active
    d = rules._div

    def fsdp_wrap(spec: P) -> P:
        """Optionally add data-axis sharding on the largest unsharded dim
        (ZeRO-3/FSDP for 405B-class models)."""
        if not rules.fsdp or rules.mesh is None:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        data_axes = tuple(a for a in ("pod", "data") if a in rules.mesh.shape)
        if not data_axes:
            return spec
        n = rules._axis_size(data_axes)
        # pick the largest dim that is unsharded and divisible
        cand = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in cand:
            if entries[i] is None and shape[i] % n == 0:
                entries[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                return P(*entries)
        return spec

    # ---- MoE expert stacks (E, d, f) / (E, f, d) ----------------------------
    # ep shards the stacked-expert dim; tp shards the experts' d_ff dim.
    # With BOTH axes set (a plan mesh) the two compose into expert-TP:
    # P(ep, None, tp) for gate/up, P(ep, tp, None) for down.
    if any(k in path for k in ("/moe/gate", "/moe/up", "/moe/down")) \
            and "shared" not in path and len(shape) == 3:
        e = [None, None, None]
        if ep is not None and d(shape[0], ep):
            e[0] = ep
        ff_dim = 2 if "down" not in path else 1
        if tp is not None and d(shape[ff_dim], tp):
            e[ff_dim] = tp
        return fsdp_wrap(P(*e))
    if "/moe/router" in path:
        return fsdp_wrap(P(None, None))

    # ---- embeddings / head ---------------------------------------------------
    # (no fsdp_wrap: gathers from two-axis-sharded tables trip an XLA SPMD
    #  partitioner bug — "Invalid binary instruction opcode copy" — and the
    #  vocab-sharded table is already small per device)
    if path.endswith("embed/table") or path.endswith("head/table"):
        if d(shape[0], mdl):
            return P(mdl, None)
        return P(None, None)

    # ---- attention -------------------------------------------------------------
    if any(path.endswith(s) for s in ("/wq", "/wk", "/wv")):
        return fsdp_wrap(P(None, tp) if d(shape[1], tp) else P(None, None))
    if path.endswith("/wo"):
        return fsdp_wrap(P(tp, None) if d(shape[0], tp) else P(None, None))

    # ---- dense MLP (also shared experts) ----------------------------------------
    if any(path.endswith(s) for s in ("/up", "/gate")) and len(shape) == 2:
        return fsdp_wrap(P(None, tp) if d(shape[1], tp) else P(None, None))
    if path.endswith("/down") and len(shape) == 2:
        return fsdp_wrap(P(tp, None) if d(shape[0], tp) else P(None, None))

    # ---- SSM mixers ---------------------------------------------------------------
    if path.endswith("/in_proj"):
        return fsdp_wrap(P(None, tp) if d(shape[1], tp) else P(None, None))
    if path.endswith("/out_proj"):
        return fsdp_wrap(P(tp, None) if d(shape[0], tp) else P(None, None))
    if path.endswith("/conv_w") or path.endswith("/x_proj") or \
            path.endswith("/dt_proj"):
        return fsdp_wrap(P(*([None] * len(shape))))

    # everything else (norms, biases, A_log, D, ...): replicated
    return P(*([None] * len(shape)))


def param_specs(params, rules: ShardingRules):
    """PartitionSpec pytree for a param tree. Layer-stacked leaves have a
    leading layer dim — specs are computed on the per-layer shape and
    shifted. When the mesh has a ``pp`` axis, the uniform ``layers`` stack's
    leading dim is sharded over it (contiguous L/pp layer slices = pipeline
    stages), so each stage's devices hold exactly its layer slice."""
    def spec_for(path_parts, leaf):
        path = "/" + "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                              for p in path_parts)
        shape = leaf.shape
        # stacked layer dims: any leading dims tagged by path containing
        # 'layers'/'groups'/'rem' get None entries prepended.
        n_stack = 0
        if any(seg in path for seg in ("layers/", "groups/", "rem/",
                                       "enc_layers/", "dec_layers/")):
            n_stack = 1
            if "groups/" in path:
                n_stack = 2        # (G, every, ...)
        stack_entries = [None] * n_stack
        if (n_stack == 1 and rules.pp_axis is not None
                and path.startswith("/layers/")
                and shape[0] % rules._axis_size(rules.pp_axis) == 0):
            stack_entries[0] = rules.pp_axis
        inner_shape = shape[n_stack:]
        # normalize the path so _param_spec's endswith-matching sees the
        # module-local names
        spec = _param_spec(path, inner_shape, rules)
        return P(*(stack_entries + list(spec)))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def shardings(params, rules: ShardingRules):
    if rules.mesh is None:
        return None
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                        param_specs(params, rules))


def batch_sharding(rules: ShardingRules):
    """NamedSharding for (B, S[, ...]) input batches: batch dim over the
    resolved batch axes, everything else replicated. None off-mesh — callers
    can always ``jax.device_put(batch, batch_sharding(rules) or ...)``."""
    if rules is None or rules.mesh is None:
        return None
    b = tuple(rules.batch_axes)
    if not b:
        return NamedSharding(rules.mesh, P())
    return NamedSharding(rules.mesh, P(b if len(b) > 1 else b[0]))
