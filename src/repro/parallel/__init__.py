"""Parallelism substrate: the declarative ParallelPlan API (plan.py), the
sharding-rule engine (sharding.py) and the jitted pipeline executor
(pipeline.py)."""
from .plan import (AXES, KernelPlan, ParallelPlan, ResolvedPlan,
                   current_kernel_plan, default_kernel_plan,
                   set_default_kernel_plan, use_kernel_plan)

__all__ = ["AXES", "KernelPlan", "ParallelPlan", "ResolvedPlan",
           "current_kernel_plan", "default_kernel_plan",
           "set_default_kernel_plan", "use_kernel_plan"]
