"""ParallelPlan — the declarative named-axis parallelism API.

One frozen object is the single source of truth for how a run maps onto
hardware, replacing the positional ``--mesh dp,pp,model`` spec + scattered
kwargs (``rules`` / ``mesh`` / ``opt_sharding_mode`` / ``pp_stages``) and
the retired module-global kernel knobs (the PR 4 compatibility aliases are
deleted; lint rule SL004 tombstones the symbols repo-wide).

Axes and their roles (every axis is explicit — no role inference on a
shared 'model' axis):

  ====  =========================================================
  axis  role
  ====  =========================================================
  pod   outermost data-parallel replication (multi-pod runs)
  dp    data parallelism — batch rows; FSDP/ZeRO-3 when ``fsdp``
  pp    pipeline stages (1f1b / gpipe over the stacked layer dim)
  ep    expert parallelism — MoE expert stacks sharded on dim 0
  tp    tensor parallelism — attention heads / MLP d_ff; composed
        with ``ep`` it shards the *experts'* d_ff (expert-TP), the
        mesh shape the legacy role-inferred API could not express
  ====  =========================================================

``ParallelPlan.parse("dp=2,pp=2,ep=2")`` / ``str(plan)`` round-trip;
``plan.resolve(cfg, train)`` builds the Mesh + ``ShardingRules`` exactly
once, and the resulting ``ResolvedPlan`` is threaded through
``train.init_state`` / ``make_train_step``, the launcher, ``Checkpointer``
(plan serialized into checkpoint metadata), ``serve.ServeEngine`` and the
dry-run tooling.

``KernelPlan`` scopes the kernel backend (tile sizes, interpret flag,
attention impl) to a plan instead of process-global mutable state:
``use_kernel_plan(plan.kernel)`` installs it for the current (tracing)
context and restores the previous one on exit — no cross-test leakage.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ----------------------------------------------------------------------------
# KernelPlan — plan-scoped replacement for the retired module-global knobs
# ----------------------------------------------------------------------------

_BACKENDS = ("ref", "pallas", "xla")
_ATTN_IMPLS = ("blockwise", "pallas")


@dataclass(frozen=True)
class KernelPlan:
    """Kernel execution knobs, scoped to a plan (not a process).

    ``backend``   'ref' — pure-JAX reference paths everywhere (CPU default);
                  'xla' — XLA-optimized lowerings (uniform-capacity MoE);
                  'pallas' — the Pallas kernels (gmm/combine/swiglu; flash
                  attention for forward-only paths).
    ``tile_*``    Pallas grouped-matmul tile sizes (MXU-aligned defaults).
    ``tiles``     None — always use the explicit ``tile_*`` fields;
                  'auto' — resolve tiles per (kernel, shape bucket) from
                  the active measured tuning table (kernels/autotune.py) at
                  trace time, falling back to the ``tile_*`` fields on any
                  miss. An auto tile_m is only applied when it divides
                  ``tile_m`` (the dispatch pads groups to ``tile_m``, so a
                  non-divisor would break the gmm alignment contract).
    ``interpret`` None -> auto (True on CPU): kernels execute their Python
                  bodies — how this container validates TPU kernels.
    ``attn_impl`` 'blockwise' (pure-JAX online softmax, has a backward) |
                  'pallas' (forward-only flash kernel, serving/prefill).
    ``hw``        HardwareSpec name (launch/roofline.py registry) whose
                  VMEM budget the tile guardrail checks and whose roofline
                  the per-kernel attribution predicts against.
    ``strict``    guardrail escalation: a tile triple whose double-buffered
                  working set exceeds the ``hw`` VMEM budget warns by
                  default; with ``strict=True`` it raises.
    """
    backend: str = "ref"
    tile_m: int = 128
    tile_k: int = 512
    tile_n: int = 512
    interpret: Optional[bool] = None
    attn_impl: str = "blockwise"
    tiles: Optional[str] = None
    hw: str = "tpu-v5e"
    strict: bool = False

    def __post_init__(self):
        if self.backend not in _BACKENDS:
            raise ValueError(f"KernelPlan.backend must be one of {_BACKENDS},"
                             f" got {self.backend!r}")
        if self.attn_impl not in _ATTN_IMPLS:
            raise ValueError(f"KernelPlan.attn_impl must be one of "
                             f"{_ATTN_IMPLS}, got {self.attn_impl!r}")
        for k in ("tile_m", "tile_k", "tile_n"):
            if getattr(self, k) < 1:
                raise ValueError(f"KernelPlan.{k} must be >= 1, "
                                 f"got {getattr(self, k)}")
        if self.tiles not in (None, "auto"):
            raise ValueError(f"KernelPlan.tiles must be None or 'auto', "
                             f"got {self.tiles!r} (explicit tiles go in "
                             f"tile_m/tile_k/tile_n)")
        # VMEM-budget guardrail: the double-buffered working set of the
        # explicit tile triple must fit the target hardware's fast memory
        # (bf16 inputs, f32 accumulator — launch/roofline.py owns the math)
        from repro.launch.roofline import (get_hardware,
                                           gmm_working_set_bytes)
        spec = get_hardware(self.hw)     # validates the name too
        ws = gmm_working_set_bytes(self.tile_m, self.tile_k, self.tile_n)
        if ws > spec.vmem_bytes:
            msg = (f"KernelPlan tiles {self.tile_m}/{self.tile_k}/"
                   f"{self.tile_n}: double-buffered working set "
                   f"{ws / 2**20:.1f}MiB exceeds {spec.name} fast memory "
                   f"{spec.vmem_bytes / 2**20:.0f}MiB — the kernel would "
                   f"spill (shrink tile_k/tile_n or pick tiles='auto')")
            if self.strict:
                raise ValueError(msg)
            import warnings
            warnings.warn(msg, stacklevel=2)

    @property
    def moe_backend(self) -> str:
        """Stage-4/5 grouped-FFN backend this kernel plan selects."""
        return "pallas" if self.backend == "pallas" else "xla"

    def resolve_tiles(self, kernel: str, dims) -> Optional[tuple]:
        """Tile tuple for ``kernel`` at ``dims`` (a dim dict, e.g.
        ``{"g": G, "m": M, "k": K, "n": N}``) from the active tuning table,
        or None — the caller keeps its built-in defaults. Only consults the
        table under ``tiles='auto'``; reads happen at trace time, so the
        resolved tiles are baked into the jaxpr like the explicit fields."""
        if self.tiles != "auto":
            return None
        from repro.kernels.autotune import lookup_tiles
        return lookup_tiles(kernel, self.backend, dims)


# The active kernel plan: a contextvar (scoped, restores on exit) over a
# mutable process default (set_default_kernel_plan).
_DEFAULT_KERNEL_PLAN = [KernelPlan()]
_ACTIVE_KERNEL_PLAN: contextvars.ContextVar[Optional[KernelPlan]] = \
    contextvars.ContextVar("repro_kernel_plan", default=None)


def current_kernel_plan() -> KernelPlan:
    """The kernel plan in effect for the current (tracing) context."""
    p = _ACTIVE_KERNEL_PLAN.get()
    return p if p is not None else _DEFAULT_KERNEL_PLAN[0]


def default_kernel_plan() -> KernelPlan:
    """The process-default kernel plan (what applies outside any
    ``use_kernel_plan`` scope)."""
    return _DEFAULT_KERNEL_PLAN[0]


def set_default_kernel_plan(plan: KernelPlan) -> None:
    """Replace the process-default kernel plan (prefer the scoped
    ``use_kernel_plan``)."""
    _DEFAULT_KERNEL_PLAN[0] = plan


@contextlib.contextmanager
def use_kernel_plan(plan: Optional[KernelPlan]):
    """Scope ``plan`` as the active kernel plan; always restores the previous
    one — the leak-free replacement for the retired mutable module globals.
    ``None`` is a no-op scope (callers can pass a maybe-plan through)."""
    if plan is None:
        yield None
        return
    tok = _ACTIVE_KERNEL_PLAN.set(plan)
    try:
        yield plan
    finally:
        _ACTIVE_KERNEL_PLAN.reset(tok)


def _apply_tiles_token(kernel: KernelPlan, value: str,
                       spec: str = "") -> KernelPlan:
    """Apply a ``tiles=`` token ('auto' or 'TMxTKxTN') to a KernelPlan —
    shared by ``ParallelPlan.parse`` and ``launch/train.py --kernel-tiles``."""
    import dataclasses
    v = value.strip()
    if v == "auto":
        return dataclasses.replace(kernel, tiles="auto")
    try:
        tm, tk, tn = (int(x) for x in v.split("x"))
    except ValueError:
        where = f" in parallel spec {spec!r}" if spec else ""
        raise ValueError(f"tiles={value!r}{where}: want 'auto' or an "
                         f"explicit 'TMxTKxTN' triple, e.g. "
                         f"tiles=128x512x512") from None
    return dataclasses.replace(kernel, tiles=None, tile_m=tm, tile_k=tk,
                               tile_n=tn)


# ----------------------------------------------------------------------------
# ParallelPlan
# ----------------------------------------------------------------------------

# canonical axis order == mesh-major order (pod outermost, tp innermost) and
# the mesh axis name each plan axis maps to.
AXES: Tuple[Tuple[str, str], ...] = (
    ("pod", "pod"), ("dp", "data"), ("pp", "pp"), ("ep", "ep"), ("tp", "tp"))
_AXIS_KEYS = tuple(k for k, _ in AXES)
_OPT_MODES = ("none", "so", "epso")
_OPT_OVERLAPS = ("auto", "off", "ring", "xla")
_PP_SCHEDULES = ("gpipe", "1f1b")
_PP_IMPLS = ("shardmap", "masked")
_MOE_DISPATCH = ("capacity", "dropless")


@dataclass(frozen=True)
class ParallelPlan:
    """Declarative parallel-execution plan. See module docstring."""
    dp: int = 1
    pp: int = 1
    ep: int = 1
    tp: int = 1
    pod: int = 1
    opt_shard: str = "none"          # none | so | epso  (paper §3.2)
    # overlapped optimizer collectives (optim/overlap.py): None/'auto' = on
    # (ring) for epso on a real mesh, off otherwise; 'ring'/'xla' force an
    # impl; 'off' keeps the eager GSPMD-derived update tail.
    opt_overlap: Optional[str] = None    # None | auto | off | ring | xla
    pp_schedule: str = "1f1b"        # gpipe | 1f1b      (paper §2.2)
    pp_impl: str = "shardmap"        # shardmap (per-stage programs) | masked
    microbatches: int = 1
    fsdp: bool = False
    # MoE dispatch the plan pins across train/serve/dryrun/checkpoints:
    # None defers to the model's MoEConfig.dispatch
    moe_dispatch: Optional[str] = None   # None | capacity | dropless
    # live EP rebalancing policy (parallel/placement.py): None/'off' = static
    # identity placement; 'N:threshold' = every N steps, re-place experts
    # when the windowed max/mean rank load exceeds threshold.
    rebalance: Optional[str] = None      # None | off | '<int>:<float>'
    kernel: KernelPlan = field(default_factory=KernelPlan)

    def __post_init__(self):
        for k in _AXIS_KEYS + ("microbatches",):
            v = getattr(self, k)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"ParallelPlan.{k} must be a positive int, "
                                 f"got {v!r}")
        if self.opt_shard not in _OPT_MODES:
            raise ValueError(f"opt_shard must be one of {_OPT_MODES}, "
                             f"got {self.opt_shard!r}")
        if self.opt_overlap not in (None,) + _OPT_OVERLAPS:
            raise ValueError(f"opt_overlap must be None or one of "
                             f"{_OPT_OVERLAPS}, got {self.opt_overlap!r}")
        if self.pp_schedule not in _PP_SCHEDULES:
            raise ValueError(f"pp_schedule must be one of {_PP_SCHEDULES}, "
                             f"got {self.pp_schedule!r}")
        if self.pp_impl not in _PP_IMPLS:
            raise ValueError(f"pp_impl must be one of {_PP_IMPLS}, "
                             f"got {self.pp_impl!r}")
        if self.moe_dispatch is not None and \
                self.moe_dispatch not in _MOE_DISPATCH:
            raise ValueError(f"moe_dispatch must be None or one of "
                             f"{_MOE_DISPATCH}, got {self.moe_dispatch!r}")
        self.rebalance_params()          # validates the token's shape

    def rebalance_params(self) -> Optional[Tuple[int, float]]:
        """The parsed ``rebalance=`` policy: ``(interval_steps, threshold)``,
        or None when rebalancing is off (token absent or 'off')."""
        r = self.rebalance
        if r is None or r == "off":
            return None
        try:
            n_s, t_s = str(r).split(":", 1)
            n, t = int(n_s), float(t_s)
        except ValueError:
            raise ValueError(
                f"rebalance={r!r}: want 'off' or '<interval>:<threshold>' "
                f"(e.g. rebalance=50:1.25 — every 50 steps, re-place when "
                f"max/mean rank load exceeds 1.25)") from None
        if n < 1 or t < 1.0:
            raise ValueError(f"rebalance={r!r}: interval must be >= 1 and "
                             f"threshold >= 1.0 (a max/mean ratio)")
        return n, t

    # ---- spec string <-> plan ------------------------------------------------
    @classmethod
    def parse(cls, spec: str, **overrides) -> "ParallelPlan":
        """``'dp=2,pp=2,ep=2'`` -> ParallelPlan. Options ride along in the
        same spec: ``opt=epso``, ``schedule=gpipe``, ``mb=4``, ``fsdp``.
        Raises a descriptive ValueError on unknown roles or bad sizes."""
        if not str(spec).strip():
            raise ValueError("empty parallel spec (want e.g. 'dp=2,pp=2,ep=2')")
        kw: dict = {}

        def put(key, val):
            if key in kw:
                raise ValueError(f"duplicate {key!r} in parallel spec "
                                 f"{spec!r} (each axis/option once)")
            kw[key] = val

        for tok in str(spec).split(","):
            tok = tok.strip()
            if not tok:
                continue
            if tok == "fsdp":
                put("fsdp", True)
                continue
            if "=" not in tok:
                raise ValueError(
                    f"bad token {tok!r} in parallel spec {spec!r}: want "
                    f"axis=size (axes: {', '.join(_AXIS_KEYS)}) or an option "
                    f"(opt=, schedule=, mb=, fsdp)")
            k, v = (s.strip() for s in tok.split("=", 1))
            if k in _AXIS_KEYS or k in ("mb", "microbatches"):
                try:
                    n = int(v)
                except ValueError:
                    raise ValueError(f"{k}={v!r} in parallel spec {spec!r}: "
                                     f"size must be an integer") from None
                if n < 1:
                    raise ValueError(f"{k}={n} in parallel spec {spec!r}: "
                                     f"axis sizes must be >= 1")
                put("microbatches" if k in ("mb", "microbatches") else k, n)
            elif k in ("opt", "opt_shard"):
                put("opt_shard", v)
            elif k in ("overlap", "opt_overlap"):
                put("opt_overlap", v)
            elif k in ("schedule", "pp_schedule", "sched"):
                put("pp_schedule", v)
            elif k in ("impl", "pp_impl"):
                put("pp_impl", v)
            elif k in ("moe", "moe_dispatch"):
                put("moe_dispatch", v)
            elif k == "rebalance":
                put("rebalance", v)
            elif k == "tiles":
                put("tiles", v)
            elif k == "fsdp":
                put("fsdp", v not in ("0", "false", "False"))
            else:
                raise ValueError(
                    f"unknown role {k!r} in parallel spec {spec!r}; valid "
                    f"axes: {', '.join(_AXIS_KEYS)}; options: opt={{none|so|"
                    f"epso}}, overlap={{auto|off|ring|xla}}, "
                    f"schedule={{gpipe|1f1b}}, "
                    f"impl={{shardmap|masked}}, moe={{capacity|dropless}}, "
                    f"rebalance={{off|N:threshold}}, "
                    f"tiles={{auto|TMxTKxTN}}, mb=<int>, fsdp")
        kw.update(overrides)
        tiles = kw.pop("tiles", None)
        if tiles is not None:
            kern = kw.get("kernel", KernelPlan())
            kw["kernel"] = _apply_tiles_token(kern, tiles, spec)
        return cls(**kw)

    def __str__(self) -> str:
        """Canonical spec; ``ParallelPlan.parse(str(p)) == p`` (modulo
        kernel-plan fields other than the tile selection, which round-trips
        via the ``tiles=`` token)."""
        parts = [f"{k}={getattr(self, k)}" for k in ("dp", "pp", "ep", "tp",
                                                     "pod")
                 if getattr(self, k) != 1]
        if not parts:
            parts = ["dp=1"]
        if self.opt_shard != "none":
            parts.append(f"opt={self.opt_shard}")
        if self.opt_overlap is not None:
            parts.append(f"overlap={self.opt_overlap}")
        if self.pp_schedule != "1f1b":
            parts.append(f"schedule={self.pp_schedule}")
        if self.pp_impl != "shardmap":
            parts.append(f"impl={self.pp_impl}")
        if self.moe_dispatch is not None:
            parts.append(f"moe={self.moe_dispatch}")
        if self.rebalance is not None:
            parts.append(f"rebalance={self.rebalance}")
        k = self.kernel
        if k.tiles == "auto":
            parts.append("tiles=auto")
        elif (k.tile_m, k.tile_k, k.tile_n) != (128, 512, 512):
            parts.append(f"tiles={k.tile_m}x{k.tile_k}x{k.tile_n}")
        if self.microbatches != 1:
            parts.append(f"mb={self.microbatches}")
        if self.fsdp:
            parts.append("fsdp")
        return ",".join(parts)

    # ---- legacy translation --------------------------------------------------
    @classmethod
    def from_legacy(cls, mesh_spec: str, *, cfg=None, opt_shard: str = "none",
                    pp_schedule: str = "1f1b", microbatches: int = 1,
                    fsdp: bool = False) -> "ParallelPlan":
        """Translate the positional ``--mesh dp[,pp][,model]`` spec (+ the
        old role inference on the 'model' axis) into an explicit plan:
        MoE configs whose expert count divides the model-axis size get
        ``ep=<model>``; everything else (dense archs, non-divisible expert
        counts — the old 'etp' fallback) gets ``tp=<model>``."""
        from repro.launch.mesh import parse_mesh_spec
        dims, axes = parse_mesh_spec(mesh_spec)
        sizes = dict(zip(axes, dims))
        model = sizes.get("model", 1)
        ep, tp = 1, 1
        if model > 1:
            if (cfg is not None and getattr(cfg, "is_moe", False)
                    and cfg.moe.num_experts % model == 0):
                ep = model
            else:
                tp = model
        return cls(dp=sizes.get("data", 1), pp=sizes.get("pp", 1),
                   ep=ep, tp=tp, pod=sizes.get("pod", 1),
                   opt_shard=opt_shard, pp_schedule=pp_schedule,
                   microbatches=microbatches, fsdp=fsdp)

    # ---- derived -------------------------------------------------------------
    @property
    def num_devices(self) -> int:
        return self.pod * self.dp * self.pp * self.ep * self.tp

    def mesh_axes(self) -> Tuple[Tuple[str, int], ...]:
        """(mesh_axis_name, size) pairs, mesh-major order, size-1 axes
        dropped (a plan that is all ones has no mesh)."""
        return tuple((name, getattr(self, key)) for key, name in AXES
                     if getattr(self, key) > 1)

    def apply_to_model(self, cfg):
        """Fold plan-pinned model options into ``cfg``. Today that is the MoE
        dispatch mode: ``moe=...`` in the spec overrides ``MoEConfig.dispatch``
        so every consumer of the plan (train, serve, dryrun, checkpoints)
        agrees on one path. Returns ``cfg`` unchanged when nothing is pinned
        or the model has no MoE block."""
        import dataclasses
        if (self.moe_dispatch is None or getattr(cfg, "moe", None) is None
                or cfg.moe.dispatch == self.moe_dispatch):
            return cfg
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=self.moe_dispatch))

    def contracts(self) -> Tuple[str, ...]:
        """Sharding-contract ids (repro.analysis.contracts registry) the
        lowered step must satisfy under this plan. The plan declares its
        own invariants so the census (``repro.analysis.census``), the
        ``dryrun --analyze`` report and the CI gate all check the same
        set; contract-id strings are stable — they are stored in
        ANALYSIS_census.json baselines."""
        ids = ["no-host-transfer"]
        if self.num_devices > 1:
            ids.append("coll-vs-costmodel")
        if self.ep > 1 or self.tp > 1:
            # the ragged_dot GSPMD hazard only bites when expert buffers
            # are actually sharded (see core/moe.py's dropless notes)
            ids.append("no-gspmd-ragged-dot")
        if self.opt_shard == "epso":
            ids.append("epso-no-full-param-gather")
        if self.rebalance_params() is not None:
            # live placements must stay valid bijections (the census
            # records the placement metadata the contract checks)
            ids.append("placement-consistency")
        return tuple(ids)

    # ---- resolution ----------------------------------------------------------
    def validate_model(self, cfg) -> None:
        """Plan-vs-model divisibility checks, with errors that say what to
        change. Called by ``resolve`` (and usable standalone pre-flight)."""
        if self.pp > 1:
            if cfg.num_layers % self.pp != 0:
                raise ValueError(
                    f"plan pp={self.pp} does not divide {cfg.name}'s "
                    f"{cfg.num_layers} layers: each pipeline stage needs "
                    f"L/pp whole layers")
        if self.rebalance_params() is not None:
            if not getattr(cfg, "is_moe", False):
                raise ValueError(
                    f"plan rebalance={self.rebalance!r} but {cfg.name} has "
                    f"no experts: rebalancing permutes MoE expert stacks")
            if self.pp > 1:
                raise NotImplementedError(
                    f"rebalance={self.rebalance!r} with pp={self.pp}: live "
                    f"placement is not threaded through the pipeline "
                    f"executors yet (stage-sharded layer stacks would need "
                    f"per-stage placement rows)")
        if self.ep > 1:
            if not getattr(cfg, "is_moe", False):
                raise ValueError(
                    f"plan ep={self.ep} but {cfg.name} has no experts: "
                    f"expert parallelism needs a MoE config (use tp/dp)")
            if cfg.moe.num_experts % self.ep != 0:
                raise ValueError(
                    f"plan ep={self.ep} does not divide {cfg.name}'s "
                    f"{cfg.moe.num_experts} experts (ep x tp = "
                    f"{self.ep}x{self.tp}): pick ep | num_experts, or move "
                    f"the ways onto tp (expert-TP shards d_ff instead)")
        if self.tp > 1:
            if getattr(cfg, "is_moe", False):
                f = cfg.moe.d_ff_expert
                if f and f % self.tp != 0:
                    raise ValueError(
                        f"plan tp={self.tp} does not divide {cfg.name}'s "
                        f"expert d_ff={f} (ep x tp = {self.ep}x{self.tp}): "
                        f"expert-TP shards each expert's d_ff {self.tp}-way")
            elif cfg.d_ff and cfg.d_ff % self.tp != 0:
                raise ValueError(
                    f"plan tp={self.tp} does not divide {cfg.name}'s "
                    f"d_ff={cfg.d_ff}")

    def resolve(self, cfg, train=None, *, global_batch=None,
                devices=None) -> "ResolvedPlan":
        """Build the Mesh and ShardingRules ONCE for this plan + model.

        Token/batch rows shard over (pod, data[, ep]) — EP gathers tokens
        over its own axis exactly as the legacy 'ep' role did over 'model'.
        ``devices`` overrides the device pool (tests); by default the CPU
        backend is asked for ``num_devices`` host devices (only effective
        before backend init — same contract as ``launch.mesh``)."""
        import jax
        from repro.compat import AxisType
        from repro.parallel.sharding import (ShardingRules, ep_batch_axes,
                                             resolve_batch_axes)

        self.validate_model(cfg)
        if global_batch is None and train is not None:
            global_batch = getattr(train, "global_batch", None)

        axes = self.mesh_axes()
        if not axes:
            return ResolvedPlan(plan=self, mesh=None, rules=None)
        shape = tuple(s for _, s in axes)
        names = tuple(n for n, _ in axes)
        if devices is None:
            from repro.launch.mesh import make_forced_mesh
            mesh = make_forced_mesh(shape, names, what=f"plan '{self}'")
        else:
            mesh = jax.make_mesh(shape, names, devices=devices,
                                 axis_types=(AxisType.Auto,) * len(shape))

        ep_axis = "ep" if self.ep > 1 else None
        tp_axis = "tp" if self.tp > 1 else None
        pp_axis = "pp" if self.pp > 1 else None
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        if ep_axis is not None:
            # EP shards tokens over its axis too (paper §1: tokens over
            # (pod, data, ep)), falling back to pure-DP rows when the batch
            # cannot span data x ep — same helper as the legacy role path
            batch = ep_batch_axes(mesh, ep_axis, global_batch, data_axes)
        else:
            batch = resolve_batch_axes(global_batch, mesh, data_axes)
        rules = ShardingRules(mesh, batch, tp_axis, ep_axis,
                              fsdp=self.fsdp, pp_axis=pp_axis, cfg=cfg)
        return ResolvedPlan(plan=self, mesh=mesh, rules=rules)


# ----------------------------------------------------------------------------
# ResolvedPlan
# ----------------------------------------------------------------------------

@dataclass(frozen=True)
class ResolvedPlan:
    """A ParallelPlan bound to a Mesh + ShardingRules (built exactly once).
    This is the object threaded through init_state / make_train_step /
    Checkpointer / ServeEngine / dryrun — replacing the per-call
    rules/mesh/opt_sharding_mode kwarg threading."""
    plan: ParallelPlan
    mesh: object = None           # jax.sharding.Mesh | None (single device)
    rules: object = None          # ShardingRules | None
    # live ExpertPlacement (parallel/placement.py) baked into the step as a
    # trace-time constant; None = identity. Rebalance events swap it via
    # ``with_placement`` and rebuild the step (rare, so the recompile is
    # cheaper than carrying the permutation as a traced input every step).
    placement: object = None      # ExpertPlacement | None

    def with_placement(self, placement) -> "ResolvedPlan":
        """This plan with a different live placement (same mesh/rules —
        a placement never changes shardings, only which expert lives at
        which position)."""
        import dataclasses
        return dataclasses.replace(self, placement=placement)

    # ---- forwarding ----------------------------------------------------------
    @property
    def opt_shard(self) -> str:
        return self.plan.opt_shard

    @property
    def opt_overlap(self) -> Optional[str]:
        return self.plan.opt_overlap

    @property
    def pp_stages(self) -> int:
        return self.plan.pp

    @property
    def microbatches(self) -> int:
        return self.plan.microbatches

    @property
    def pp_schedule(self) -> str:
        return self.plan.pp_schedule

    @property
    def pp_impl(self) -> str:
        return self.plan.pp_impl

    @property
    def kernel(self) -> KernelPlan:
        return self.plan.kernel

    def parallel_config(self, *, remat_policy: str = "block"):
        """The ParallelConfig this plan implies for make_train_step."""
        from repro.configs.base import ParallelConfig
        return ParallelConfig(microbatches=self.microbatches,
                              remat_policy=remat_policy,
                              optimizer_sharding=self.opt_shard,
                              opt_overlap=self.plan.opt_overlap,
                              pp_stages=self.pp_stages,
                              pp_schedule=self.pp_schedule,
                              pp_impl=self.pp_impl,
                              moe_dispatch=self.plan.moe_dispatch)

    # ---- checkpoint metadata -------------------------------------------------
    def layout_signature(self) -> dict:
        """The axis layout a checkpoint records: what must agree between the
        saving and restoring plan for shardings to be interchangeable."""
        return {"axes": [[n, s] for n, s in self.plan.mesh_axes()],
                "opt_shard": self.plan.opt_shard,
                "fsdp": bool(self.plan.fsdp)}

    def spec(self) -> str:
        return str(self.plan)

    # ---- dry-run description -------------------------------------------------
    def describe(self, cfg, train=None, *, params=None) -> str:
        """Human-readable resolution report: axis table, per-param placement
        and projected bytes/device. Shape-only (jax.eval_shape) — zero
        allocation, safe for CI smoke."""
        import jax
        import numpy as np
        from repro.parallel.sharding import param_specs
        from repro.optim.epso import (optimizer_state_specs,
                                      state_bytes_per_device)

        lines = [f"plan     : {self.plan}",
                 f"devices  : {self.plan.num_devices}"]
        if self.mesh is None:
            lines.append("mesh     : none (single device)")
            return "\n".join(lines)
        lines.append("mesh     : " + " x ".join(
            f"{n}={s}" for n, s in self.plan.mesh_axes()))
        r = self.rules
        lines.append(f"batch    : rows over {tuple(r.batch_axes) or '(replicated)'}"
                     f"  tp={r.tp_axis or '-'} ep={r.ep_axis or '-'} "
                     f"pp={r.pp_axis or '-'} fsdp={r.fsdp}")
        if params is None:
            from repro.models import init_params
            params = jax.eval_shape(
                lambda: init_params(jax.random.PRNGKey(0), cfg))
        pspecs = param_specs(params, r)
        ospecs = optimizer_state_specs(params, r, self.plan.opt_shard)

        def ndev(spec):
            n = 1
            for e in spec:
                for a in (e if isinstance(e, tuple) else (e,)):
                    if a is not None:
                        n *= self.mesh.shape[a]
            return n

        lines.append(f"{'param':44s} {'shape':>20s} {'placement':24s} "
                     f"opt({self.plan.opt_shard})")
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        pflat = jax.tree.leaves(pspecs)
        oflat = jax.tree.leaves(ospecs)
        param_bytes = 0
        for (path, leaf), ps, os_ in zip(flat, pflat, oflat):
            key = jax.tree_util.keystr(path)
            param_bytes += int(np.prod(leaf.shape)) * 4 // ndev(ps)
            lines.append(f"{key:44s} {str(tuple(leaf.shape)):>20s} "
                         f"{str(ps):24s} {os_}")
        opt_bytes = state_bytes_per_device(params, r, self.plan.opt_shard)
        lines.append(f"projected bytes/device: params(fp32)="
                     f"{param_bytes / 2**20:.1f}MiB  "
                     f"opt-states={opt_bytes / 2**20:.1f}MiB")
        return "\n".join(lines)
