"""Telemetry-driven expert placement and live EP rebalancing.

The EP axis shards the stacked expert dim in *position* order: rank ``r``
hosts positions ``[r*EL, (r+1)*EL)`` of every ``(L, E, ...)`` expert stack.
By default position == global expert id (identity placement), so a hot
expert pins its rank at the top of every dispatch all-to-all while cold
ranks idle — the load imbalance Pangu Ultra MoE (arXiv:2505.04519) shows
dropless dispatch cannot pay for on its own.

``ExpertPlacement`` decouples the two spaces: ``perm[l][pos]`` is the
global expert id stored at placed position ``pos`` of layer ``l``. The
model only ever needs the inverse map (``inverse_array()``: global id ->
position) — the router keeps producing global ids and every dispatch path
translates them to positions at dispatch time, so router weights, routing
decisions and telemetry stay in global-id space while the expert stacks
(and their EPSO-sharded optimizer states) live wherever the placement puts
them.

Rebalancing is *numerics-preserving by construction*: a placement change
is pure data movement (same experts, new homes). Token->expert assignment,
per-expert pool order (stable argsort over translated ids preserves
within-expert token order), capacity-drop sets and the expert-local matmuls
are all invariant; for ``experts_per_token <= 2`` the EP combine-psum's
per-token sum is a reordering of at most two addends plus exact ``+0.0``
terms, so losses are bit-identical across a rebalance event (pinned by
``tests/test_placement.py``). For ``top_k >= 3`` the combine may
reassociate (still exact to float addition reordering, not bitwise). On
the update side, the global grad-norm (clip scale) is made
placement-invariant by construction: expert-stack leaves contribute
per-(layer, expert) slice sums reduced in global-id order in both the
eager and overlapped optimizer paths (``expert_leaf_mask`` +
``adamw.expert_slice_sumsq``), so moving expert shards between ranks
cannot reassociate the norm.

The host-side loop (``RebalanceController``): aggregate the per-step
``moe_counts`` telemetry over a window of N steps; at each window boundary
compute the rank-level imbalance (max/mean rank load under the live
placement — the component of expert imbalance a placement can actually
fix); when it exceeds the threshold, propose a greedy LPT placement
(experts by descending windowed load onto the least-loaded rank with free
slots) and adopt it only if it strictly improves the imbalance — intrinsic
routing skew below what LPT can fix must not re-trigger every window.

Telemetry counts are summed over layers (the scan accumulates one
``MoeStats``), so the controller broadcasts one permutation to all layers;
the ``ExpertPlacement`` API itself is per-layer and the model threads
per-layer rows through the layer scan, so heterogeneous placements (e.g.
from offline per-layer profiles) work everywhere downstream.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


def _as_rows(perm) -> Tuple[Tuple[int, ...], ...]:
    return tuple(tuple(int(v) for v in row) for row in perm)


@dataclasses.dataclass(frozen=True)
class ExpertPlacement:
    """Per-layer expert->position permutation. ``perm[l][pos]`` = global
    expert id physically stored at placed position ``pos`` (EP rank
    ``pos // (E/ep)``) in layer ``l``. Identity by default everywhere a
    placement is optional."""
    num_layers: int
    num_experts: int
    perm: Tuple[Tuple[int, ...], ...]

    def __post_init__(self):
        rows = _as_rows(self.perm)
        object.__setattr__(self, "perm", rows)
        if len(rows) != self.num_layers:
            raise ValueError(f"placement has {len(rows)} rows for "
                             f"num_layers={self.num_layers}")
        want = tuple(range(self.num_experts))
        for l, row in enumerate(rows):
            if tuple(sorted(row)) != want:
                raise ValueError(
                    f"placement row {l} is not a permutation of "
                    f"0..{self.num_experts - 1}: {row}")

    # ---- constructors ------------------------------------------------------
    @classmethod
    def identity(cls, num_layers: int, num_experts: int) -> "ExpertPlacement":
        row = tuple(range(num_experts))
        return cls(num_layers, num_experts, (row,) * num_layers)

    @classmethod
    def broadcast(cls, row: Sequence[int],
                  num_layers: int) -> "ExpertPlacement":
        """One permutation applied to every layer (the telemetry-driven
        case: counts are layer-summed, so the controller proposes one row)."""
        r = tuple(int(v) for v in row)
        return cls(num_layers, len(r), (r,) * num_layers)

    # ---- views -------------------------------------------------------------
    @property
    def is_identity(self) -> bool:
        ident = tuple(range(self.num_experts))
        return all(row == ident for row in self.perm)

    def perm_array(self) -> np.ndarray:
        """(L, E) int32: position -> global expert id."""
        return np.array(self.perm, dtype=np.int32)

    def inverse_array(self) -> np.ndarray:
        """(L, E) int32: global expert id -> placed position. This is the
        only map the model needs (dispatch-time id translation)."""
        return np.argsort(self.perm_array(), axis=1).astype(np.int32)

    def relative_to(self, new: "ExpertPlacement") -> np.ndarray:
        """(L, E) int32 gather map moving *live* arrays from this placement
        to ``new``: ``W_new[l, pos] = W_live[l, rel[l, pos]]``. Derivation:
        ``W_live[p] = W_global[perm[p]]`` and we want
        ``W_new[pos] = W_global[new.perm[pos]]``, so
        ``rel[pos] = inv[new.perm[pos]]``."""
        if (new.num_layers, new.num_experts) != (self.num_layers,
                                                 self.num_experts):
            raise ValueError(f"placement shape mismatch: "
                             f"({self.num_layers},{self.num_experts}) vs "
                             f"({new.num_layers},{new.num_experts})")
        inv = self.inverse_array()
        return np.take_along_axis(inv, new.perm_array(), axis=1)

    # ---- manifest serialization (checkpoint/checkpointer.py) ---------------
    def to_manifest(self) -> dict:
        return {"num_layers": self.num_layers,
                "num_experts": self.num_experts,
                "perm": [list(row) for row in self.perm]}

    @classmethod
    def from_manifest(cls, d: Optional[dict]) -> Optional["ExpertPlacement"]:
        if d is None:
            return None
        return cls(int(d["num_layers"]), int(d["num_experts"]),
                   _as_rows(d["perm"]))


# ----------------------------------------------------------------------------
# load metrics + the greedy (LPT) balancing permutation
# ----------------------------------------------------------------------------

def rank_loads(counts, perm_row: Sequence[int], ep: int) -> np.ndarray:
    """(ep,) summed expert load per EP rank under one placement row.
    ``counts`` is in global-id space (the telemetry's space)."""
    c = np.array(counts, dtype=np.float64)
    E = c.shape[0]
    if E % ep:
        raise ValueError(f"ep={ep} does not divide num_experts={E}")
    placed = c[np.array(perm_row, dtype=np.int64)]     # position order
    return placed.reshape(ep, E // ep).sum(axis=1)


def imbalance(counts, perm_row: Sequence[int], ep: int) -> float:
    """max/mean rank load (>= 1.0; 1.0 = perfectly balanced or no load)."""
    loads = rank_loads(counts, perm_row, ep)
    mean = loads.mean()
    return float(loads.max() / mean) if mean > 0 else 1.0


def greedy_perm(counts, ep: int) -> Tuple[int, ...]:
    """LPT scheduling: experts by descending windowed load, each assigned to
    the least-loaded rank with a free slot (E/ep slots per rank). Ties break
    deterministically (stable sort; lowest rank id). Within a rank, slots
    are ordered by global id for a canonical form. Returns a position ->
    global-id row."""
    c = np.array(counts, dtype=np.float64)
    E = c.shape[0]
    if E % ep:
        raise ValueError(f"ep={ep} does not divide num_experts={E}")
    slots = E // ep
    order = np.argsort(-c, kind="stable")
    loads = np.zeros(ep)
    members = [[] for _ in range(ep)]
    for g in order:
        open_ranks = [r for r in range(ep) if len(members[r]) < slots]
        r = min(open_ranks, key=lambda r: (loads[r], r))
        members[r].append(int(g))
        loads[r] += c[g]
    return tuple(v for m in members for v in sorted(m))


# ----------------------------------------------------------------------------
# applying a placement change to live state
# ----------------------------------------------------------------------------

def is_expert_stack(path: str, shape, num_layers: int,
                    num_experts: int) -> bool:
    """True for the routed expert-stack leaves a placement permutes:
    ``layers/moe/{gate,up,down}`` with a leading (L, E, ...) — never the
    router (global-id space by design), never shared experts (not routed)."""
    if "moe" not in path or "shared" in path:
        return False
    leaf = path.rsplit("/", 1)[-1]
    return (leaf in ("gate", "up", "down") and len(shape) >= 3
            and shape[0] == num_layers and shape[1] == num_experts)


def permute_expert_tree(tree, rel: np.ndarray, num_layers: int,
                        num_experts: int):
    """Gather every expert-stack leaf's E dim by ``rel`` (see
    ``ExpertPlacement.relative_to``): ``leaf[l, pos] <- leaf[l, rel[l, pos]]``.
    Non-expert leaves pass through untouched. Works on a params tree or any
    tree mirroring it (EPSO master/m/v)."""
    import jax
    import jax.numpy as jnp

    idx = jnp.array(rel, dtype=jnp.int32)

    def visit(path_parts, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_parts)
        if not is_expert_stack(path, leaf.shape, num_layers, num_experts):
            return leaf
        return jax.vmap(lambda w, p: jnp.take(w, p, axis=0))(leaf, idx)

    return jax.tree_util.tree_map_with_path(visit, tree)


def expert_leaf_mask(tree, num_layers: int,
                     num_experts: int) -> Tuple[bool, ...]:
    """Per-leaf booleans in ``jax.tree.flatten`` order: True where the leaf
    is a routed expert stack (see ``is_expert_stack``). The optimizer paths
    use this to give expert leaves a placement-invariant grad-norm
    contribution (per-(layer, expert) slice sums reduced in global-id
    order), so the clip scale cannot reassociate when a rebalance moves
    expert shards across ranks."""
    import jax

    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path_parts, leaf in flat:
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_parts)
        out.append(bool(is_expert_stack(path, leaf.shape, num_layers,
                                        num_experts)))
    return tuple(out)


def apply_placement(state, current: ExpertPlacement, new: ExpertPlacement,
                    num_layers: int, num_experts: int):
    """Move a live TrainState from ``current`` to ``new`` placement: the
    expert stacks in ``params`` AND the EPSO-sharded optimizer state move
    together (master/m/v mirror the param tree, and the EPSO state specs
    extend the param specs, so the same dim-1 gather applies uniformly —
    each state shard follows its param to the new rank). Pure data movement:
    no arithmetic, numerics-preserving by construction. The caller jits this
    (launch/train.py does, donating the state and pinning out_shardings) so
    XLA lowers the cross-rank gathers to the placement all-to-all."""
    from repro.optim.epso import permute_expert_states
    rel = current.relative_to(new)
    mv = lambda t: permute_expert_tree(t, rel, num_layers, num_experts)
    new_opt = permute_expert_states(state.opt, rel, num_layers=num_layers,
                                    num_experts=num_experts)
    return state._replace(params=mv(state.params), opt=new_opt)


# ----------------------------------------------------------------------------
# host-side windowed controller (launch/train.py)
# ----------------------------------------------------------------------------

class RebalanceController:
    """Aggregates per-step ``moe_counts`` (global-id space, host side) over
    ``interval``-step windows and proposes greedy placements when the live
    rank imbalance exceeds ``threshold``. Owns the live placement."""

    def __init__(self, *, num_layers: int, num_experts: int, ep: int,
                 interval: int, threshold: float,
                 placement: Optional[ExpertPlacement] = None):
        if interval < 1:
            raise ValueError(f"rebalance interval must be >= 1, "
                             f"got {interval}")
        if threshold < 1.0:
            raise ValueError(f"rebalance threshold is a max/mean ratio, "
                             f"must be >= 1.0, got {threshold}")
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.ep = ep
        self.interval = interval
        self.threshold = threshold
        self.placement = placement or ExpertPlacement.identity(num_layers,
                                                               num_experts)
        self.window = np.zeros(num_experts, dtype=np.float64)
        self.steps_in_window = 0
        self.rebalances = 0

    def observe(self, counts) -> float:
        """Fold one step's (E,) counts into the window; returns the live
        rank imbalance of this step's counts under the current placement
        (the per-step log metric)."""
        c = np.array(counts, dtype=np.float64)
        self.window += c
        self.steps_in_window += 1
        return imbalance(c, self.placement.perm[0], self.ep)

    def window_full(self) -> bool:
        return self.steps_in_window >= self.interval

    def reset_window(self) -> None:
        """Drop the partial window (relaunch/rollback: the replayed steps
        would otherwise be double-counted)."""
        self.window = np.zeros(self.num_experts, dtype=np.float64)
        self.steps_in_window = 0

    def propose(self, *, force: bool = False) -> Optional[ExpertPlacement]:
        """At a window boundary (or forced): greedy placement from the
        windowed counts. Adopts + returns the new placement when it strictly
        improves the windowed rank imbalance and (unless forced) the current
        imbalance exceeds the threshold; otherwise returns None. Resets the
        window either way."""
        counts, n = self.window, self.steps_in_window
        self.window = np.zeros(self.num_experts, dtype=np.float64)
        self.steps_in_window = 0
        if n == 0 or counts.sum() <= 0:
            return None
        cur = imbalance(counts, self.placement.perm[0], self.ep)
        if not force and cur <= self.threshold:
            return None
        row = greedy_perm(counts, self.ep)
        if imbalance(counts, row, self.ep) >= cur and not (
                force and row != self.placement.perm[0]):
            return None
        new = ExpertPlacement.broadcast(row, self.num_layers)
        if new == self.placement:
            return None
        self.placement = new
        self.rebalances += 1
        return new
