"""LR schedule from the paper (§2.1): linear warmup for ``warmup_steps`` to
``lr_peak``, then cosine decay to ``lr_min`` over ``total_steps``."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, lr_peak=4e-4, lr_min=4e-5, warmup_steps=2500,
                  total_steps=630_000):
    step = jnp.asarray(step, jnp.float32)
    warm = lr_peak * step / max(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = lr_min + 0.5 * (lr_peak - lr_min) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup_steps, warm, cos)
