"""Overlapped SO/EPSO optimizer update — the EPSO step-time fix.

The eager path (train/trainer.py tail + optim/adamw.py) leaves the paper's
reduce-scatter/all-gather entirely to GSPMD: the global-norm clip forces a
full gradient reduction, every state leaf gets its own derived reshard, and
the updated-param all-gathers land one-per-leaf on the critical path after
the last backward op — the committed ``BENCH_epso.json`` regression (EPSO
~17% slower than unsharded despite the 4.9x state-bytes win).

This module replaces that tail with an explicit bucket schedule executed in
one fully-manual ``shard_map`` region over the whole mesh:

* gradients enter the region under the *state* specs — GSPMD lowers the
  placement mismatch to a reduce-scatter, so each device receives exactly
  its 1/N update shard and never materializes replicated gradients;
* the global grad-norm is computed from the shards: per-leaf local square
  sums, one scalar ``psum`` per distinct state-axis set — the full-tensor
  norm compute and its implied all-reduce disappear;
* each shard runs the identical elementwise AdamW (``adamw_leaf``) on its
  slice of every leaf in the bucket;
* the updated master shards are cast to the param dtype, flattened, and
  concatenated into ONE buffer per bucket, which is all-gathered over the
  bucket's extra axes — either a hierarchical ``ppermute`` ring
  (``impl='ring'``: n-1 neighbor exchanges per axis, the pattern async
  backends pipeline bucket-by-bucket against backward compute) or a single
  ``lax.all_gather`` (``impl='xla'``: the fallback where the ring pattern is
  unsupported or the backend's native all-gather is already async);
* the gathered buffer is split and reassembled into the param-local leaves.

Because buckets only depend on their own leaves' gradient shards (plus the
one clip scalar), the scheduler is free to start a bucket's gather while
other buckets (and, on async backends, the tail of backward) are still
computing — nothing serializes on a single whole-tree gather.

Expert placement (parallel/placement.py): a live EP rebalance permutes the
expert stacks (and, via ``epso.permute_expert_states``, master/m/v) along
their existing expert dim — shapes and specs are unchanged, so the bucket
schedule (``UpdatePlan``) and this region's lowering are placement-
invariant; the rebuilt step after a rebalance re-plans to the identical
buckets (pinned by tests/test_placement.py). Expert-stack leaves take a
*canonical* grad-norm path (``expert_norm``): per-(layer, expert) slice
sums gathered into a replicated (L, E) table, reordered to global-id
order, reduced in fixed order — so the clip scale is bit-identical across
a rebalance even though the shard-local partials regroup. The update
math is ``adamw_leaf`` with the same clip/LR scalars as the eager path; the
only numerical difference is the non-expert grad-norm's reduction order
(shard-wise partial sums instead of whole-leaf sums), so eager and
overlapped updates agree to ~1 ulp and checkpoint resume stays
bit-identical.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import manual_shard_map
from repro.optim.adamw import AdamWState, adamw_leaf
from repro.optim.epso import (DEFAULT_BUCKET_BYTES, UpdatePlan, _entry_axes,
                              optimizer_state_specs, plan_update_buckets,
                              update_axis_order)
from repro.parallel.sharding import param_specs

OVERLAP_IMPLS = ("off", "ring", "xla")


def resolve_opt_overlap(setting: Optional[str], mode: str, mesh) -> str:
    """Resolve an ``opt_overlap`` request to 'off' | 'ring' | 'xla'.

    ``None``/'auto' turns the overlap on (ring) for ``epso`` on a real mesh
    with update axes — the mode whose collectives regressed — and leaves
    'so' eager as the parity baseline. Explicit 'ring'/'xla' require a
    sharded optimizer mode and a mesh; explicit 'off' always wins.
    """
    s = "auto" if setting is None else str(setting)
    if s == "off":
        return "off"
    has_axes = mesh is not None and bool(update_axis_order(mesh))
    if s == "auto":
        return "ring" if (mode == "epso" and has_axes) else "off"
    if s not in ("ring", "xla"):
        raise ValueError(f"opt_overlap must be one of "
                         f"{('auto',) + OVERLAP_IMPLS}, got {setting!r}")
    if mode not in ("so", "epso"):
        raise ValueError(f"opt_overlap={s!r} needs opt_shard in "
                         f"{{'so','epso'}} (got {mode!r}): the overlap "
                         f"schedules the sharded-state collectives")
    if not has_axes:
        raise ValueError(f"opt_overlap={s!r} needs a mesh with update axes "
                         f"(pod/data/model/ep/tp)")
    return s


def _ring_all_gather(flat, axes, coords, axis_sizes):
    """Hierarchical ppermute ring over ``axes`` (canonical rank order).

    Gathers the minor-most axis first; after each level every shard holds
    that level's full ring reordered to rank order (roll by own coord), so
    the final leading dim enumerates shards major-to-minor over ``axes`` —
    the same linearization a GSPMD tuple spec uses.
    """
    cur = flat[None]                            # (1, S)
    for a in reversed(axes):
        n = axis_sizes[a]
        if n == 1:
            continue
        perm = [(s, (s - 1) % n) for s in range(n)]
        parts = [cur]
        p = cur
        for _ in range(n - 1):
            p = jax.lax.ppermute(p, a, perm)
            parts.append(p)                     # parts[k] = shard (r+k) % n
        stacked = jnp.roll(jnp.stack(parts), coords[a], axis=0)
        cur = stacked.reshape((n * cur.shape[0],) + cur.shape[1:])
    return cur                                  # (prod(axes), S)


def _assemble_leaf(seg, bucket_axes, leaf, blk_shape, axis_sizes):
    """Post-gather reassembly: (N, *blk) -> param-local leaf, moving each
    rank-index axis next to the dim it split (spec major-to-minor order,
    matching the state spec's tiling) and merging."""
    sizes = tuple(axis_sizes[a] for a in bucket_axes)
    t = seg.reshape(sizes + blk_shape)
    k = len(sizes)
    added = dict(leaf.added)
    perm, out_shape = [], []
    for d in range(len(blk_shape)):
        mult = 1
        for a in added.get(d, ()):
            perm.append(bucket_axes.index(a))
            mult *= axis_sizes[a]
        perm.append(k + d)
        out_shape.append(mult * blk_shape[d])
    return t.transpose(perm).reshape(out_shape)


def overlapped_adamw_update(grads, state: AdamWState, *, rules, mode: str,
                            impl: str = "ring", lr, beta1=0.9, beta2=0.99,
                            eps=1e-8, weight_decay=0.1, grad_clip=1.0,
                            clip_enabled=None, param_dtype=jnp.float32,
                            update_plan: Optional[UpdatePlan] = None,
                            max_bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                            expert_norm=None):
    """Drop-in replacement for ``adamw_update`` with bucketed, overlappable
    collectives. Same signature plus ``rules``/``mode``/``impl`` and an
    optional precomputed ``update_plan`` (built once at step-build time).
    ``expert_norm`` is the ``(mask, inv)`` pair from
    ``adamw.global_norm``: flagged expert-stack leaves contribute to the
    grad-norm via per-(layer, expert) slice sums gathered to a replicated
    (L, E) table, reordered to global-id order, and reduced in fixed order —
    the same association the eager path uses, and invariant under live
    expert placement, so the clip scale cannot drift across a rebalance.
    Returns (new_params(param_dtype), new_state, metrics) with identical
    semantics; see the module docstring for the one numerical difference
    (grad-norm reduction order on non-expert leaves)."""
    if impl not in ("ring", "xla"):
        raise ValueError(f"impl must be 'ring' or 'xla', got {impl!r}")
    mesh = rules.mesh
    if update_plan is None:
        update_plan = plan_update_buckets(grads, rules, mode,
                                          max_bucket_bytes=max_bucket_bytes)
    axis_sizes = dict(mesh.shape)

    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - beta1 ** t
    bc2 = 1.0 - beta2 ** t

    flat_g, treedef = jax.tree.flatten(grads)
    flat_ma = jax.tree.leaves(state.master)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    pspecs = tuple(jax.tree.leaves(param_specs(grads, rules)))
    ospecs = tuple(jax.tree.leaves(
        optimizer_state_specs(grads, rules, mode)))
    n = len(flat_g)
    assert update_plan.n_leaves == n, (update_plan.n_leaves, n)

    ex_mask = expert_norm[0] if expert_norm is not None else ()
    expert_ids = frozenset(i for i, m in enumerate(ex_mask) if m)
    inv_const = None
    if expert_norm is not None and expert_norm[1] is not None:
        inv_const = jnp.asarray(expert_norm[1], jnp.int32)

    all_leaves = [lf for b in update_plan.buckets for lf in b.leaves]
    norm_groups = {}          # psum axis set -> leaf indices (non-expert)
    expert_leaves = []        # canonical slice-sum norm path (global order)
    for lf in all_leaves:
        if lf.index in expert_ids:
            expert_leaves.append(lf)
        else:
            norm_groups.setdefault(lf.psum_axes, []).append(lf.index)
    expert_leaves.sort(key=lambda lf: lf.index)

    def region(gs, ma, mo, vo, scalars):
        lrv, b1c, b2c, clip_on = scalars
        coords = {a: jax.lax.axis_index(a) for a in update_plan.axes} \
            if impl == "ring" else {}
        # global grad norm from the shards: one scalar psum per distinct
        # state-axis set (shards tile the tensor exactly over those axes)
        total = jnp.zeros((), jnp.float32)
        for axes, idxs in sorted(norm_groups.items()):
            loc = jnp.zeros((), jnp.float32)
            for i in idxs:
                loc = loc + jnp.sum(jnp.square(gs[i].astype(jnp.float32)))
            total = total + (jax.lax.psum(loc, axes) if axes else loc)
        # expert stacks: per-(L, E)-slice sums, un-sharded to a replicated
        # (L, E) table (gather over the axes tiling dims 0/1, psum over the
        # axes tiling the trailing dims), reordered to global-id order, then
        # one fixed-order reduction — placement moves slices between ranks
        # but never changes the association, so gnorm (and the clip scale)
        # is bit-identical across a live rebalance
        for lf in expert_leaves:
            i = lf.index
            s = jnp.sum(jnp.square(gs[i].astype(jnp.float32)),
                        axis=tuple(range(2, gs[i].ndim)))
            spec = ospecs[i]
            lead = []
            for d in (0, 1):
                ent = spec[d] if d < len(spec) else None
                for a in reversed(_entry_axes(ent)):
                    s = jax.lax.all_gather(s, a, axis=d, tiled=True)
                    lead.append(a)
            trail = tuple(a for a in lf.psum_axes if a not in lead)
            if trail:
                s = jax.lax.psum(s, trail)
            if inv_const is not None:
                s = jnp.take_along_axis(s, inv_const, axis=1)
            total = total + jnp.sum(s)
        gnorm = jnp.sqrt(total)
        if grad_clip <= 0:
            sc = jnp.float32(1.0)
        else:
            sc = jnp.where(gnorm > grad_clip,
                           grad_clip / (gnorm + 1e-12), 1.0)
            sc = jnp.where(clip_on, sc, 1.0)

        new_p = [None] * n
        new_ma = [None] * n
        new_m = [None] * n
        new_v = [None] * n
        for bucket in update_plan.buckets:
            pieces, blk_shapes = [], []
            for leaf in bucket.leaves:
                i = leaf.index
                nma, nm2, nv2 = adamw_leaf(
                    gs[i], ma[i], mo[i], vo[i], scale=sc, lr=lrv, bc1=b1c,
                    bc2=b2c, beta1=beta1, beta2=beta2, eps=eps,
                    weight_decay=weight_decay)
                new_ma[i], new_m[i], new_v[i] = nma, nm2, nv2
                if bucket.axes:
                    pieces.append(nma.astype(param_dtype).reshape(-1))
                    blk_shapes.append(nma.shape)
                else:
                    new_p[i] = nma.astype(param_dtype)
            if not bucket.axes:
                continue
            flat = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)
            if impl == "ring":
                full = _ring_all_gather(flat, bucket.axes, coords, axis_sizes)
            else:
                full = jax.lax.all_gather(flat, bucket.axes)
            off = 0
            for leaf, blk in zip(bucket.leaves, blk_shapes):
                sz = 1
                for d in blk:
                    sz *= d
                seg = full[:, off:off + sz].reshape((full.shape[0],) + blk)
                new_p[leaf.index] = _assemble_leaf(
                    seg, bucket.axes, leaf, blk, axis_sizes)
                off += sz
        return (tuple(new_p), tuple(new_ma), tuple(new_m), tuple(new_v),
                gnorm, sc)

    scal_specs = (P(), P(), P(), P())
    # grads enter under the STATE specs: GSPMD lowers the mismatch against
    # the backward's partial sums to a reduce-scatter (the paper's grad RS)
    fn = manual_shard_map(
        region, mesh,
        in_specs=(ospecs, ospecs, ospecs, ospecs, scal_specs),
        out_specs=(pspecs, ospecs, ospecs, ospecs, P(), P()))
    clip_arg = jnp.asarray(True if clip_enabled is None else clip_enabled)
    scalars = (jnp.asarray(lr, jnp.float32),
               jnp.asarray(bc1, jnp.float32),
               jnp.asarray(bc2, jnp.float32), clip_arg)
    new_p, new_ma, new_m, new_v, gnorm, scale = fn(
        tuple(flat_g), tuple(flat_ma), tuple(flat_m), tuple(flat_v), scalars)
    new_params = treedef.unflatten(list(new_p))
    new_state = AdamWState(step, treedef.unflatten(list(new_ma)),
                           treedef.unflatten(list(new_m)),
                           treedef.unflatten(list(new_v)))
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return new_params, new_state, metrics
