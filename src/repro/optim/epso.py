"""Sharded Optimizer (SO) and EP-Aware Sharded Optimizer (EPSO) — paper §3.2.

In JAX, optimizer-state *placement* is expressed as PartitionSpecs on the
state pytree; XLA derives the paper's reduce-scatter (gradients) and
all-gather (updated params) from the sharding mismatch between grads/params
and states. The update math (repro/optim/adamw.py) is identical in both
modes — exactly as in the paper, where EPSO changes only who owns which
shard.

* ``mode='so'``   — baseline: every state leaf is sharded across the DP axes
  only (('pod','data')). A parameter that is replicated over the 'model'
  axis keeps its states replicated over 'model' too — the EP-times waste the
  paper identifies.
* ``mode='epso'`` — states of 'model'-replicated parameters are additionally
  sharded over 'model' (DP×EP-way, fine-grained sharding); states of
  'model'-sharded parameters (the experts under EP, TP shards) keep their
  model sharding and gain DP sharding on another dim — matching Figure 6.

Greedy dim assignment: each extra mesh axis (or axis group) is placed on the
largest divisible, still-unsharded dim of the leaf. Leaves too small to
divide stay replicated (negligible memory).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.sharding import ShardingRules, param_specs


def _augment(spec: P, shape, axes_groups, mesh) -> P:
    """Add ``axes_groups`` (list of tuples of mesh axes) to a param spec."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a is not None:
                used.add(a)
    for group in axes_groups:
        group = tuple(a for a in group if a not in used and a in mesh.shape)
        if not group:
            continue
        size = 1
        for a in group:
            size *= mesh.shape[a]
        # largest unsharded divisible dim
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if entries[i] is None and shape[i] % size == 0 and size > 1:
                entries[i] = group if len(group) > 1 else group[0]
                used.update(group)
                break
        else:
            # try splitting the group (e.g. only 'data' fits, not 'model')
            for a in group:
                for i in order:
                    if entries[i] is None and shape[i] % mesh.shape[a] == 0 \
                            and mesh.shape[a] > 1:
                        entries[i] = a
                        used.add(a)
                        break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def optimizer_state_specs(params, rules: ShardingRules, mode: str = "epso"):
    """PartitionSpec pytree for each of master/m/v given the param tree."""
    if rules.mesh is None:
        return jax.tree.map(lambda _: P(), params)
    mesh = rules.mesh
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    # the model-like axes: the legacy shared 'model' axis, or the plan
    # mesh's dedicated 'ep'/'tp' axes — EPSO treats them uniformly.
    model_axes = tuple(a for a in ("model", "ep", "tp") if a in mesh.shape)
    pspecs = param_specs(params, rules)

    def one(spec: P, leaf):
        shape = leaf.shape
        if mode == "so":
            groups = [dp_axes]
        elif mode == "epso":
            # one joint group: DP axes + the model-like axes where the param
            # is replicated over them; _augment skips axes already used by
            # the param spec (model-sharded experts keep their sharding and
            # gain DP on another dim).
            groups = [dp_axes + model_axes]
        elif mode == "none":
            return spec
        else:
            raise ValueError(mode)
        return _augment(spec, shape, groups, mesh)

    return jax.tree.map(one, pspecs, params)


def optimizer_state_shardings(params, rules: ShardingRules, mode: str):
    if rules.mesh is None:
        return None
    specs = optimizer_state_specs(params, rules, mode)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs)


def state_bytes_per_device(params, rules: ShardingRules, mode: str) -> int:
    """Analytic per-device bytes for the fp32 (master, m, v) states — the
    EPSO-vs-SO memory comparison (paper Table 3 counterpart)."""
    if rules.mesh is None:
        total = sum(l.size for l in jax.tree.leaves(params))
        return total * 12
    mesh = rules.mesh
    specs = optimizer_state_specs(params, rules, mode)

    def shard_elems(spec, leaf):
        n = leaf.size
        denom = 1
        for e in spec:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a is not None:
                    denom *= mesh.shape[a]
        return n // denom

    per_dev = sum(jax.tree.leaves(
        jax.tree.map(shard_elems, specs, params)))
    return per_dev * 12    # 4B * (master + m + v)
