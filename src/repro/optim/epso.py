"""Sharded Optimizer (SO) and EP-Aware Sharded Optimizer (EPSO) — paper §3.2.

In JAX, optimizer-state *placement* is expressed as PartitionSpecs on the
state pytree; XLA derives the paper's reduce-scatter (gradients) and
all-gather (updated params) from the sharding mismatch between grads/params
and states. The update math (repro/optim/adamw.py) is identical in both
modes — exactly as in the paper, where EPSO changes only who owns which
shard.

* ``mode='so'``   — baseline: every state leaf is sharded across the DP axes
  only (('pod','data')). A parameter that is replicated over the 'model'
  axis keeps its states replicated over 'model' too — the EP-times waste the
  paper identifies.
* ``mode='epso'`` — states of 'model'-replicated parameters are additionally
  sharded over 'model' (DP×EP-way, fine-grained sharding); states of
  'model'-sharded parameters (the experts under EP, TP shards) keep their
  model sharding and gain DP sharding on another dim — matching Figure 6.

Greedy dim assignment: each extra mesh axis (or axis group) is placed on the
largest divisible, still-unsharded dim of the leaf. Leaves too small to
divide stay replicated (negligible memory).

``plan_update_buckets`` turns the spec-level placement into the bucket
schedule the overlapped update (repro/optim/overlap.py) executes: leaves are
grouped by their *extra* sharding (state spec minus param spec), packed into
size-capped buckets in flatten order, so each bucket's updated-param
all-gather is one fused collective independent of every other bucket's.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.sharding import ShardingRules, param_specs


def _augment(spec: P, shape, axes_groups, mesh) -> P:
    """Add ``axes_groups`` (list of tuples of mesh axes) to a param spec."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a is not None:
                used.add(a)
    for group in axes_groups:
        # order-preserving dedupe: a repeated axis inside one group must not
        # be placed twice (P(('data','data')) is XLA-invalid)
        fill, seen = [], set()
        for a in group:
            if a not in used and a in mesh.shape and a not in seen:
                fill.append(a)
                seen.add(a)
        group = tuple(fill)
        if not group:
            continue
        size = 1
        for a in group:
            size *= mesh.shape[a]
        # largest unsharded divisible dim
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if entries[i] is None and shape[i] % size == 0 and size > 1:
                entries[i] = group if len(group) > 1 else group[0]
                used.update(group)
                break
        else:
            # try splitting the group (e.g. only 'data' fits, not 'model')
            for a in group:
                for i in order:
                    if entries[i] is None and shape[i] % mesh.shape[a] == 0 \
                            and mesh.shape[a] > 1:
                        entries[i] = a
                        used.add(a)
                        break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def optimizer_state_specs(params, rules: ShardingRules, mode: str = "epso"):
    """PartitionSpec pytree for each of master/m/v given the param tree."""
    if rules.mesh is None:
        return jax.tree.map(lambda _: P(), params)
    mesh = rules.mesh
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    # the model-like axes: the legacy shared 'model' axis, or the plan
    # mesh's dedicated 'ep'/'tp' axes — EPSO treats them uniformly.
    model_axes = tuple(a for a in ("model", "ep", "tp") if a in mesh.shape)
    pspecs = param_specs(params, rules)

    def one(spec: P, leaf):
        shape = leaf.shape
        if mode == "so":
            groups = [dp_axes]
        elif mode == "epso":
            # one joint group: DP axes + the model-like axes where the param
            # is replicated over them; _augment skips axes already used by
            # the param spec (model-sharded experts keep their sharding and
            # gain DP on another dim).
            groups = [dp_axes + model_axes]
        elif mode == "none":
            return spec
        else:
            raise ValueError(mode)
        return _augment(spec, shape, groups, mesh)

    return jax.tree.map(one, pspecs, params)


def permute_expert_states(opt_state, rel, *, num_layers: int,
                          num_experts: int):
    """Move the SO/EPSO-sharded AdamW states with their params across an
    expert-placement change (parallel/placement.py).

    master/m/v mirror the param tree and the SO/EPSO state specs *extend*
    the param specs (``_augment`` only adds axes to still-unsharded dims),
    so the identical expert-dim gather ``rel`` applies to the states keeps
    every fp32 shard glued to its (possibly bf16) param — on an EPSO mesh
    XLA lowers the jitted gather to the placement all-to-all for states
    exactly as for params. Pure data movement; the update-bucket schedule
    (``plan_update_buckets``) is invariant because it reads only shapes and
    specs, which a permutation along an existing dim cannot change."""
    from repro.parallel.placement import permute_expert_tree
    mv = lambda t: permute_expert_tree(t, rel, num_layers, num_experts)
    return opt_state._replace(master=mv(opt_state.master),
                              m=mv(opt_state.m), v=mv(opt_state.v))


def optimizer_state_shardings(params, rules: ShardingRules, mode: str):
    if rules.mesh is None:
        return None
    specs = optimizer_state_specs(params, rules, mode)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs)


def state_bytes_per_device(params, rules: ShardingRules, mode: str) -> int:
    """Analytic per-device bytes for the fp32 (master, m, v) states — the
    EPSO-vs-SO memory comparison (paper Table 3 counterpart)."""
    if rules.mesh is None:
        total = sum(l.size for l in jax.tree.leaves(params))
        return total * 12
    mesh = rules.mesh
    specs = optimizer_state_specs(params, rules, mode)

    def shard_elems(spec, leaf):
        n = leaf.size
        denom = 1
        for e in spec:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a is not None:
                    denom *= mesh.shape[a]
        return n // denom

    per_dev = sum(jax.tree.leaves(
        jax.tree.map(shard_elems, specs, params)))
    return per_dev * 12    # 4B * (master + m + v)


# ---------------------------------------------------------------------------
# Bucket planner for the overlapped update (repro/optim/overlap.py)
# ---------------------------------------------------------------------------

# canonical linear-rank order over the update axes: mesh-major, matching the
# major-to-minor order of a GSPMD tuple spec — so the fused gather's leading
# index enumerates shards exactly as the per-leaf tuple-spec placement does.
_UPDATE_AXIS_ORDER = ("pod", "data", "model", "ep", "tp")

DEFAULT_BUCKET_BYTES = 4 << 20


def update_axis_order(mesh) -> Tuple[str, ...]:
    """The mesh's update axes (axes SO/EPSO may add to a state spec), in the
    canonical rank order the overlapped gather linearizes over."""
    return tuple(a for a in _UPDATE_AXIS_ORDER if a in mesh.shape)


class UpdateLeaf(NamedTuple):
    """One param-tree leaf inside an update bucket.

    ``added`` records the extra sharding the optimizer-state spec carries on
    top of the param spec: ``((dim, (axis, ...)), ...)`` — the axes (in spec
    major-to-minor order) that further split param-local dim ``dim``. The
    union of added axes equals the owning bucket's ``axes``. ``psum_axes``
    is every mesh axis the *state* spec uses (param + added): the axes a
    scalar reduction over this leaf's shards must psum over to be global.
    """
    index: int                 # position in jax.tree flatten order
    path: str                  # human-readable key path (diagnostics)
    added: Tuple[Tuple[int, Tuple[str, ...]], ...]
    psum_axes: Tuple[str, ...]


class UpdateBucket(NamedTuple):
    axes: Tuple[str, ...]      # gather axes, canonical order; () = local-only
    leaves: Tuple[UpdateLeaf, ...]
    elems: int                 # global elements across the bucket's leaves


class UpdatePlan(NamedTuple):
    buckets: Tuple[UpdateBucket, ...]
    axes: Tuple[str, ...]      # union of all buckets' axes
    n_leaves: int
    mode: str


def _entry_axes(e):
    return tuple(a for a in (e if isinstance(e, tuple) else (e,))
                 if a is not None)


def plan_update_buckets(params, rules: ShardingRules, mode: str, *,
                        max_bucket_bytes: int = DEFAULT_BUCKET_BYTES
                        ) -> UpdatePlan:
    """Group the param tree into size-capped update buckets.

    Leaves are keyed by their extra-axes signature (the mesh axes the state
    spec adds over the param spec — the axes whose all-gather reassembles the
    updated params) and packed greedily in flatten order, ``max_bucket_bytes``
    of fp32 master weights per bucket; a single leaf larger than the cap gets
    its own bucket. Leaves whose state spec equals their param spec form
    ``axes=()`` buckets (pure local update, no collective).

    Note on "layer order": the model stacks layers into single leaves
    (params['layers'][...] have a leading L dim), so flatten order — the
    order gradients materialize from one backward pass over the stack — is
    the bucket order; buckets are mutually dataflow-independent either way,
    which is what lets the scheduler overlap their collectives.
    """
    mesh = rules.mesh
    order = update_axis_order(mesh)
    pspecs = jax.tree.leaves(param_specs(params, rules))
    ospecs = jax.tree.leaves(optimizer_state_specs(params, rules, mode))
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    assert len(flat) == len(pspecs) == len(ospecs)

    mesh_order = tuple(mesh.shape)
    leaves = []
    for i, ((path, leaf), ps, os_) in enumerate(zip(flat, pspecs, ospecs)):
        shape = leaf.shape
        added = []
        for d in range(len(shape)):
            pe = _entry_axes(ps[d]) if d < len(ps) else ()
            oe = _entry_axes(os_[d]) if d < len(os_) else ()
            if oe[:len(pe)] != pe:
                raise ValueError(
                    f"state spec {os_} does not extend param spec {ps} at "
                    f"dim {d} of {jax.tree_util.keystr(path)}")
            extra = oe[len(pe):]
            if extra:
                denom = 1
                for a in oe:
                    denom *= mesh.shape[a]
                if shape[d] % denom != 0:
                    raise ValueError(
                        f"dim {d} of {jax.tree_util.keystr(path)} ({shape}) "
                        f"not divisible by state spec {os_}")
                added.append((d, extra))
        state_axes = {a for e in os_ for a in _entry_axes(e)}
        psum_axes = tuple(a for a in mesh_order if a in state_axes)
        leaves.append(UpdateLeaf(i, jax.tree_util.keystr(path),
                                 tuple(added), psum_axes))

    max_elems = max(max_bucket_bytes // 4, 1)
    buckets = []
    open_buckets = {}      # signature -> (leaves, elems)
    for lf, (path, leaf) in zip(leaves, flat):
        sig = tuple(a for a in order
                    if any(a in axes for _, axes in lf.added))
        cur = open_buckets.get(sig)
        size = int(leaf.size) if hasattr(leaf, "size") else 1
        if cur is not None and cur[1] + size > max_elems and cur[0]:
            buckets.append(UpdateBucket(sig, tuple(cur[0]), cur[1]))
            cur = None
        if cur is None:
            cur = ([], 0)
        cur[0].append(lf)
        open_buckets[sig] = (cur[0], cur[1] + size)
    for sig, (ls, elems) in open_buckets.items():
        if ls:
            buckets.append(UpdateBucket(sig, tuple(ls), elems))
    # deterministic schedule: buckets in flatten order of their first leaf
    buckets.sort(key=lambda b: b.leaves[0].index)
    union = tuple(a for a in order if any(a in b.axes for b in buckets))
    return UpdatePlan(tuple(buckets), union, len(leaves), mode)
