"""AdamW with BF16-mixed-precision semantics matching the paper (§1, §2.1):

* bf16 weights/gradients in the fwd/bwd pass,
* fp32 master weights + fp32 (m, v) optimizer states (16 bytes/param total),
* bf16 gradient reduction (the paper deviates from OLMoE's fp32 reduction),
* global-norm gradient clipping, optionally only after warmup (paper recipe),
* decoupled weight decay applied to all parameters (paper: wd=0.1 on all).

State layout: a pytree of per-parameter dicts {master, m, v}. Sharding of
these states is what distinguishes SO from EPSO (see repro/optim/epso.py) —
the update math is identical; pjit placement of the state does the rest.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array            # int32 scalar
    master: dict               # fp32 master weights (pytree like params)
    m: dict                    # fp32 first moment
    v: dict                    # fp32 second moment


def adamw_init(params) -> AdamWState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(f32, params),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def expert_slice_sumsq(g, inv=None) -> jax.Array:
    """Squared-sum of an (L, E, ...) expert-stack grad with a *canonical*
    association: per-(layer, expert) slice sums first, reordered to
    global-id order when a live placement permutes the stack (``inv`` is
    the (L, E) global-id -> position map), then one fixed-order (L, E)
    reduction. A placement change moves slices between ranks but never
    changes which elements a slice sum covers or the order the slice sums
    combine in, so the grad-norm — and through it the clip scale — is
    bit-identical across a rebalance."""
    s = jnp.sum(jnp.square(g.astype(jnp.float32)),
                axis=tuple(range(2, g.ndim)))
    if inv is not None:
        s = jnp.take_along_axis(s, inv, axis=1)
    return jnp.sum(s)


def global_norm(grads, *, expert_norm=None) -> jax.Array:
    """Global L2 norm of a grad tree. ``expert_norm``, when given, is a
    ``(mask, inv)`` pair (see ``parallel.placement.expert_leaf_mask``):
    leaves flagged in ``mask`` contribute via ``expert_slice_sumsq`` so the
    norm is invariant under live expert placement; ``None`` keeps the plain
    whole-leaf sums."""
    mask = expert_norm[0] if expert_norm is not None else ()
    inv = expert_norm[1] if expert_norm is not None else None
    leaves = [expert_slice_sumsq(g, inv) if i < len(mask) and mask[i]
              else jnp.sum(jnp.square(g.astype(jnp.float32)))
              for i, g in enumerate(jax.tree.leaves(grads))]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_scale(gnorm, grad_clip, clip_enabled):
    """The global-norm clip multiplier (shared by the eager and overlapped
    update paths so their math cannot diverge)."""
    scale = jnp.where(gnorm > grad_clip, grad_clip / (gnorm + 1e-12), 1.0)
    if grad_clip <= 0:
        return 1.0
    if clip_enabled is not None:
        scale = jnp.where(clip_enabled, scale, 1.0)
    return scale


def adamw_leaf(g, master, m, v, *, scale, lr, bc1, bc2, beta1, beta2, eps,
               weight_decay):
    """Elementwise AdamW on one leaf (or one shard of a leaf — the update is
    pointwise, so SO/EPSO shards update independently). The single source of
    the update math for both adamw_update and the overlapped bucket path."""
    g = g.astype(jnp.float32) * scale
    m2 = beta1 * m + (1 - beta1) * g
    v2 = beta2 * v + (1 - beta2) * jnp.square(g)
    mhat = m2 / bc1
    vhat = v2 / bc2
    new_master = master - lr * (mhat / (jnp.sqrt(vhat) + eps)
                                + weight_decay * master)
    return new_master, m2, v2


def adamw_update(grads, state: AdamWState, *, lr, beta1=0.9, beta2=0.99,
                 eps=1e-8, weight_decay=0.1, grad_clip=1.0,
                 clip_enabled=None, param_dtype=jnp.float32,
                 expert_norm=None):
    """One optimizer step. ``lr`` may be a traced scalar (schedule output).
    ``clip_enabled``: optional traced bool (paper clips only after warmup).
    ``expert_norm``: optional ``(mask, inv)`` making the grad-norm invariant
    under live expert placement (see ``global_norm``).
    Returns (new_params(param_dtype), new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads, expert_norm=expert_norm)
    scale = clip_scale(gnorm, grad_clip, clip_enabled)

    t = step.astype(jnp.float32)
    bc1 = 1.0 - beta1 ** t
    bc2 = 1.0 - beta2 ** t

    def upd(g, master, m, v):
        return adamw_leaf(g, master, m, v, scale=scale, lr=lr, bc1=bc1,
                          bc2=bc2, beta1=beta1, beta2=beta2, eps=eps,
                          weight_decay=weight_decay)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_ma = jax.tree.leaves(state.master)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(g, ma, m, v) for g, ma, m, v in
           zip(flat_g, flat_ma, flat_m, flat_v)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda p: p.astype(param_dtype), new_master)
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return new_params, AdamWState(step, new_master, new_m, new_v), metrics
