from .adamw import (AdamWState, adamw_init, adamw_leaf, adamw_update,
                    clip_scale, global_norm)
from .schedule import warmup_cosine
from .epso import (optimizer_state_specs, optimizer_state_shardings,
                   state_bytes_per_device, plan_update_buckets,
                   update_axis_order, UpdatePlan, UpdateBucket, UpdateLeaf)
from .overlap import overlapped_adamw_update, resolve_opt_overlap
