from .adamw import AdamWState, adamw_init, adamw_update
from .schedule import warmup_cosine
from .epso import (optimizer_state_specs, optimizer_state_shardings,
                   state_bytes_per_device)
