"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk computation.

The chunked SSD formulation (models/ssm.py::_ssd_chunked) splits the
selective scan into dense intra-chunk matmuls + a short inter-chunk
recurrence. This kernel fuses the intra-chunk stage per (batch, chunk,
head) grid cell so the (L,L) decay/score matrices never leave VMEM:

    la      = cumsum(dt * A)                       (L,)
    decay   = tril(exp(la_i - la_j))               (L,L)  — VMEM only
    y_diag  = ((C B^T) ∘ decay) @ (dt * x)         (L,P)
    states  = (exp(la_L - la) * dt * x)^T @ B      (P,N)  — chunk final
    cdecay  = exp(la_L)                            ()

VMEM per grid step ≈ L·P + 2·L·N (bf16) + 2·L·L f32 ≈ 0.7 MiB at
(L,P,N) = (256, 64, 64). The inter-chunk recurrence and off-diagonal
read-out stay in jnp (matmul-light). Forward-only (training uses the jnp
path — same math; this is the serving/prefill hot loop for hybrid archs).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref,
                y_ref, st_ref, cd_ref):
    h = pl.program_id(2)
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)      # (L, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)       # (L,)
    bm = b_ref[0, 0].astype(jnp.float32)              # (L, N)
    cm = c_ref[0, 0].astype(jnp.float32)              # (L, N)
    a = a_ref[h]                                      # scalar (negative)

    L = x.shape[0]
    la = jnp.cumsum(dt * a)                           # (L,)
    seg = la[:, None] - la[None, :]
    causal = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(causal, jnp.exp(seg), 0.0)      # (L, L) VMEM-resident
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)
    dtx = dt[:, None] * x                             # (L, P)
    y = jnp.dot(cb * decay, dtx, preferred_element_type=jnp.float32)
    w = jnp.exp(la[-1] - la)                          # (L,)
    st = jnp.dot((w[:, None] * dtx).T, bm,
                 preferred_element_type=jnp.float32)  # (P, N)

    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)
    st_ref[0, 0, 0] = st.astype(st_ref.dtype)
    cd_ref[0, 0, 0] = jnp.exp(la[-1]).astype(cd_ref.dtype)


def ssd_intra_chunk_pallas(x, dt, Bm, Cm, A, *, interpret: bool = False):
    """x: (B, C, L, H, P); dt: (B, C, L, H); Bm/Cm: (B, C, L, N); A: (H,).
    Returns (y_diag (B,C,L,H,P), states (B,C,H,P,N), chunk_decay (B,C,H))."""
    B, C, L, H, P = x.shape
    N = Bm.shape[-1]
    y, st, cd = pl.pallas_call(
        _ssd_kernel,
        grid=(B, C, H),
        in_specs=[
            pl.BlockSpec((1, 1, L, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, L, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((H,), lambda b, c, h: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, c, h: (b, c, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, C, L, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, C, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((B, C, H), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, Bm, Cm, A.astype(jnp.float32))
    return y, st, cd
