"""Fused SwiGLU elementwise Pallas kernel: silu(gate) * up.

Fuses the two Stage-4 activation reads into one VMEM pass between the
gate/up grouped GEMMs and the down-projection GEMM (on GPU the paper fuses
this into its expert-computation stage; on TPU it saves one HBM round-trip
of the (pool_rows × d_ff) activation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(g_ref, u_ref, out_ref):
    g = g_ref[...].astype(jnp.float32)
    out_ref[...] = (g * jax.lax.logistic(g) *
                    u_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


def swiglu_pallas(gate: jax.Array, up: jax.Array, *, tile_m: int = 512,
                  tile_n: int = 512, interpret: bool = False) -> jax.Array:
    M, N = gate.shape
    tm, tn = min(tile_m, M), min(tile_n, N)
    assert M % tm == 0 and N % tn == 0
    return pl.pallas_call(
        _swiglu_kernel,
        grid=(M // tm, N // tn),
        in_specs=[pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
                  pl.BlockSpec((tm, tn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), gate.dtype),
        interpret=interpret,
    )(gate, up)
