"""Pallas TPU output-reduction kernels (paper §3.1 Stage 5, fwd + bwd).

Forward (paper ExpertOutputReductionForward, lines 82-96): each output
element out[t, h] = sum_k weights[t, k] * rows[t, k, h]. The GPU kernel maps
one thread per (t, h) element; the TPU kernel tiles (t, h) into VMEM blocks
and reduces over the K axis with a vectorized multiply-add.

Backward (paper ExpertOutputReductionBackward, lines 98-113): produces
d_rows[t, k, h] = weights[t, k] * dout[t, h] and
d_weights[t, k] = sum_h rows[t, k, h] * dout[t, h] in one pass, mirroring
the paper's fused backward kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_fwd_kernel(rows_ref, w_ref, out_ref):
    rows = rows_ref[...].astype(jnp.float32)     # (TT, K, TD)
    w = w_ref[...].astype(jnp.float32)           # (TT, K)
    out_ref[...] = jnp.einsum("tkd,tk->td", rows, w).astype(out_ref.dtype)


def combine_fwd_pallas(rows: jax.Array, weights: jax.Array, *,
                       tile_t: int = 256, tile_d: int = 512,
                       interpret: bool = False) -> jax.Array:
    T, K, D = rows.shape
    tt, td = min(tile_t, T), min(tile_d, D)
    assert T % tt == 0 and D % td == 0
    return pl.pallas_call(
        _combine_fwd_kernel,
        grid=(T // tt, D // td),
        in_specs=[pl.BlockSpec((tt, K, td), lambda t, d: (t, 0, d)),
                  pl.BlockSpec((tt, K), lambda t, d: (t, 0))],
        out_specs=pl.BlockSpec((tt, td), lambda t, d: (t, d)),
        out_shape=jax.ShapeDtypeStruct((T, D), rows.dtype),
        interpret=interpret,
    )(rows, weights)


def _combine_bwd_kernel(rows_ref, w_ref, dout_ref, drows_ref, dw_ref, *,
                        n_d: int):
    d = pl.program_id(1)
    rows = rows_ref[...].astype(jnp.float32)     # (TT, K, TD)
    w = w_ref[...].astype(jnp.float32)           # (TT, K)
    dout = dout_ref[...].astype(jnp.float32)     # (TT, TD)
    drows_ref[...] = (w[:, :, None] * dout[:, None, :]).astype(drows_ref.dtype)

    @pl.when(d == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    dw_ref[...] += jnp.einsum("tkd,td->tk", rows, dout).astype(dw_ref.dtype)


def combine_bwd_pallas(rows: jax.Array, weights: jax.Array, dout: jax.Array,
                       *, tile_t: int = 256, tile_d: int = 512,
                       interpret: bool = False):
    T, K, D = rows.shape
    tt, td = min(tile_t, T), min(tile_d, D)
    assert T % tt == 0 and D % td == 0
    import functools
    return pl.pallas_call(
        functools.partial(_combine_bwd_kernel, n_d=D // td),
        grid=(T // tt, D // td),
        in_specs=[pl.BlockSpec((tt, K, td), lambda t, d: (t, 0, d)),
                  pl.BlockSpec((tt, K), lambda t, d: (t, 0)),
                  pl.BlockSpec((tt, td), lambda t, d: (t, d))],
        out_specs=[pl.BlockSpec((tt, K, td), lambda t, d: (t, 0, d)),
                   pl.BlockSpec((tt, K), lambda t, d: (t, 0))],
        out_shape=[jax.ShapeDtypeStruct((T, K, D), rows.dtype),
                   jax.ShapeDtypeStruct((T, K), jnp.float32)],
        interpret=interpret,
    )(rows, weights, dout)
