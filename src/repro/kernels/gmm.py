"""Pallas TPU grouped matmul (paper §3.1 Stage 4: Grouped_mm).

The paper merges per-rank expert weights into single tensors and runs one
grouped GEMM over the routed-token rows. On TPU the pointer-chasing GPU
grouped GEMM becomes a *tile→group map*: row tiles are group-aligned (the
dispatch pads each expert's rows to ``tile_m``), a scalar-prefetched
``group_ids`` array tells each m-tile which expert's weight block to stream
into VMEM, and the MXU sees plain (tm × tk) @ (tk × tn) tiles.

VMEM working set per grid step: tm*tk (lhs) + tk*tn (rhs) + tm*tn (acc f32),
e.g. 128*512*2B + 512*128*2B + 128*128*4B ≈ 0.3 MB — far under the ~16 MB
v5e VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(group_ids_ref, lhs_ref, rhs_ref, out_ref, acc_ref, *,
                n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(lhs_ref[...].astype(jnp.float32),
                            rhs_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def gmm_pallas(lhs: jax.Array, rhs: jax.Array, group_ids: jax.Array, *,
               tile_m: int, tile_k: int, tile_n: int,
               interpret: bool = False) -> jax.Array:
    """lhs: (M, K) with M % tile_m == 0 and every m-tile belonging to exactly
    one group (group-aligned layout); rhs: (G, K, N); group_ids: (M/tile_m,)
    int32 tile→group map (scalar-prefetched)."""
    from jax.experimental.pallas import tpu as pltpu
    M, K = lhs.shape
    G, K2, N = rhs.shape
    assert K == K2 and M % tile_m == 0 and K % tile_k == 0 and N % tile_n == 0
    n_m, n_k, n_n = M // tile_m, K // tile_k, N // tile_n

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda m, n, k, gid: (m, k)),
            pl.BlockSpec((1, tile_k, tile_n),
                         lambda m, n, k, gid: (gid[m], k, n)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda m, n, k, gid: (m, n)),
        scratch_shapes=[pltpu.VMEM((tile_m, tile_n), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_gmm_kernel, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), lhs.dtype),
        interpret=interpret,
    )(group_ids, lhs, rhs)


# ----------------------------------------------------------------------------
# tgmm: per-group weight gradient  out[g] = lhs_g^T @ rhs_g
# ----------------------------------------------------------------------------

def _tgmm_kernel(group_ids_ref, lhs_ref, rhs_ref, out_ref, acc_ref, *,
                 n_m: int):
    m = pl.program_id(2)
    first = jnp.logical_or(
        m == 0, group_ids_ref[jnp.maximum(m, 1) - 1] != group_ids_ref[m])

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(lhs_ref[...].astype(jnp.float32).T,
                            rhs_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    last = jnp.logical_or(
        m == n_m - 1,
        group_ids_ref[jnp.minimum(m + 1, n_m - 1)] != group_ids_ref[m])

    @pl.when(last)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def tgmm_pallas(lhs: jax.Array, rhs: jax.Array, group_ids: jax.Array,
                num_groups: int, *, tile_m: int, tile_k: int, tile_n: int,
                interpret: bool = False) -> jax.Array:
    """lhs: (M, K); rhs: (M, N); group-aligned m-tiles; out: (G, K, N).

    Grid order (k, n, m): for a fixed (k, n) output tile the m-sweep visits
    each group's tiles consecutively, so the output block for group g is
    initialized at the group's first tile and flushed at its last — the
    sequential-grid accumulation pattern Pallas TPU guarantees.
    """
    from jax.experimental.pallas import tpu as pltpu
    M, K = lhs.shape
    N = rhs.shape[1]
    assert M % tile_m == 0 and K % tile_k == 0 and N % tile_n == 0
    n_m, n_k, n_n = M // tile_m, K // tile_k, N // tile_n

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_k, n_n, n_m),
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda k, n, m, gid: (m, k)),
            pl.BlockSpec((tile_m, tile_n), lambda k, n, m, gid: (m, n)),
        ],
        out_specs=pl.BlockSpec((1, tile_k, tile_n),
                               lambda k, n, m, gid: (gid[m], k, n)),
        scratch_shapes=[pltpu.VMEM((tile_k, tile_n), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_tgmm_kernel, n_m=n_m),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_groups, K, N), lhs.dtype),
        interpret=interpret,
    )(group_ids, lhs, rhs)
