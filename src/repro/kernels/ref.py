"""Pure-jnp oracles for every Pallas kernel (the ``assert_allclose`` targets).

These define the *semantics*; the kernels in this package are tiled TPU
implementations of exactly these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gmm_ref(lhs: jax.Array, rhs: jax.Array, group_sizes: jax.Array) -> jax.Array:
    """Grouped matmul (paper Stage 4). lhs: (M, K) rows grouped by expert;
    rhs: (G, K, N); group_sizes: (G,) with sum <= M. Rows beyond
    sum(group_sizes) produce zeros."""
    return jax.lax.ragged_dot(lhs, rhs.astype(lhs.dtype), group_sizes)


def tgmm_ref(lhs: jax.Array, rhs: jax.Array, group_sizes: jax.Array,
             num_groups: int) -> jax.Array:
    """Transposed grouped matmul (Stage 4 weight gradient):
    out[g] = lhs[rows of g].T @ rhs[rows of g]. lhs: (M, K); rhs: (M, N)."""
    M = lhs.shape[0]
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(M)
    # (G, M) membership mask
    member = (row[None, :] >= starts[:, None]) & (row[None, :] < ends[:, None])
    lhs_g = member[:, :, None] * lhs[None, :, :].astype(jnp.float32)
    return jnp.einsum("gmk,mn->gkn", lhs_g,
                      rhs.astype(jnp.float32)).astype(lhs.dtype)


def token_counts_ref(indices: jax.Array, num_local: int,
                     offset) -> jax.Array:
    """Stage 2 histogram: count of flat routing choices per local expert."""
    local = indices.astype(jnp.int32) - offset
    valid = (local >= 0) & (local < num_local)
    return jnp.bincount(jnp.where(valid, local, num_local),
                        length=num_local + 1)[:num_local].astype(jnp.int32)


def combine_ref(rows: jax.Array, weights: jax.Array) -> jax.Array:
    """Stage 5 output reduction: rows (T, K, D), weights (T, K) ->
    out (T, D) = sum_k weights[t,k] * rows[t,k,:]."""
    return jnp.einsum("tkd,tk->td", rows, weights.astype(rows.dtype))


def combine_bwd_ref(rows, weights, dout):
    """Stage 5 backward (paper lines 98-113): gradients wrt expert rows and
    router weights."""
    drows = weights[..., None].astype(dout.dtype) * dout[:, None, :]
    dw = jnp.einsum("tkd,td->tk", rows.astype(jnp.float32),
                    dout.astype(jnp.float32)).astype(weights.dtype)
    return drows, dw


def swiglu_ref(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int = 0) -> jax.Array:
    """Dense softmax attention. q: (BH, Sq, hd); k/v: (BH, Skv, hd)."""
    import math as _m
    Sq, Skv = q.shape[1], k.shape[1]
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / _m.sqrt(q.shape[-1])
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= qp - kp < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def slot_decode_attention_ref(q, k_cache, v_cache, positions, *,
                              ring: bool = False) -> jax.Array:
    """Single-token cached GQA attention with *per-row* positions — the
    serve engine's decode hot path (one jitted step over a churning
    continuous batch; repro/serve/engine.py).

    q: (B, nh, hd) current-token queries (post-RoPE, unscaled);
    k_cache/v_cache: (B, S, nkv, hd) slot-row caches; positions: (B,) int32
    absolute position of the current token per row. ``ring=True`` treats the
    cache as a sliding-window ring buffer where absolute position p lives at
    slot ``p % S`` (so slot s currently holds the largest p <= positions[b]
    with p % S == s); otherwise slot s holds absolute position s. Cache
    entries beyond a row's position (or outside its window) are masked.
    Returns (B, nh, hd) in fp32-accumulated, q-dtype output.
    """
    import math as _m
    B, nh, hd = q.shape
    S, nkv = k_cache.shape[1], k_cache.shape[2]
    groups = nh // nkv
    idx = positions.astype(jnp.int32)
    slots = jnp.arange(S)[None, :]                       # (1, S)
    if ring:
        sl = (idx % S)[:, None]
        wrap = jnp.where(slots <= sl, slots, slots - S)
        abs_pos = idx[:, None] - sl + wrap
    else:
        abs_pos = jnp.broadcast_to(slots, (B, S))
    valid = (abs_pos >= 0) & (abs_pos <= idx[:, None])   # (B, S)

    qf = q.reshape(B, nkv, groups, hd).astype(jnp.float32) / _m.sqrt(hd)
    s = jnp.einsum("bngh,bsnh->bngs", qf, k_cache.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngs,bsnh->bngh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, nh, hd).astype(q.dtype)
