"""Measured kernel autotuning: tile search, tuning tables, shape buckets.

The paper attributes up to 1.71x of its MoE speedup to hand-tuned expert
kernels; this module closes the same loop mechanically. ``autotune()`` times
candidate tile configs per (kernel, shape-bucket, backend) with the
bench_epso discipline (explicit warmup, ``block_until_ready``, median of N)
and records the winner in a versioned JSON :class:`TuningTable`.
``KernelPlan(tiles='auto')`` then consults the active table at trace time
(``lookup_tiles`` via ``KernelPlan.resolve_tiles``) and falls back to the
plan's explicit tile fields on any miss — an absent or stale table can
never change numerics, only leave performance on the table.

Shape buckets
    Kernels see a continuum of shapes; the table is keyed by *buckets*:
    every dim rounded up to a power of two (``m`` — the token/row dim — is
    dynamic across batch sizes, so lookups that miss on ``m`` fall back to
    the nearest-``m`` entry whose other dims match exactly). Bucket keys
    render as e.g. ``g2_k512_m256_n2048``.

Candidate pruning
    Before anything compiles, candidates whose double-buffered working set
    (``roofline.gmm_working_set_bytes``) exceeds the target
    ``HardwareSpec.vmem_bytes`` are dropped — the same analytic budget the
    ``KernelPlan`` guardrail warns on.

Alignment contract (gmm)
    The MoE dispatch pads group sizes to multiples of ``plan.tile_m``
    (``gmm_align``), and the Pallas gmm requires ``group_sizes % tile_m ==
    0``. A table tile_m is therefore only applied when it divides the
    plan's tile_m (see ``ops._gmm_fwd_impl``); ``autotune`` only measures
    candidates whose tile_m divides the uniform per-group row count.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

TABLE_VERSION = 1

# the committed table `tiles='auto'` resolves from by default (regenerate
# with benchmarks/bench_kernels.py --write-table)
DEFAULT_TABLE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                  "tuning_table.json")

# dims each kernel is bucketed on, in bucket-key order; "m"-like dims
# (dynamic row counts) get the nearest-match fallback
KERNEL_DIMS = {
    "gmm": ("g", "k", "m", "n"),
    "tgmm": ("g", "k", "m", "n"),
    "fused_swiglu": ("m", "n"),
    "combine": ("d", "k", "t"),
}
_DYNAMIC_DIM = {"gmm": "m", "tgmm": "m", "fused_swiglu": "m", "combine": "t"}


def pow2_bucket(n: int) -> int:
    """Round up to a power of two (bucket boundary)."""
    p = 1
    while p < n:
        p *= 2
    return p


def bucket_dims(kernel: str, dims: Dict[str, int]) -> Dict[str, int]:
    return {k: pow2_bucket(int(dims[k])) for k in KERNEL_DIMS[kernel]}


def bucket_key(kernel: str, dims: Dict[str, int]) -> str:
    b = bucket_dims(kernel, dims)
    return "_".join(f"{k}{b[k]}" for k in KERNEL_DIMS[kernel])


# ----------------------------------------------------------------------------
# tuning table
# ----------------------------------------------------------------------------

@dataclass
class TuningTable:
    """Versioned measured-tile table. ``entries`` rows carry::

        {kernel, backend, bucket: {dim: pow2}, tiles: [..],
         time_ms, default_tiles, default_time_ms, shape: {dim: measured},
         n_iters, hw, gflops, achieved_frac}

    Only ``kernel``/``backend``/``bucket``/``tiles`` are load-bearing for
    lookup; the rest is provenance surfaced by ``dryrun --parallel``.
    """
    hw: str = "tpu-v5e"
    entries: List[dict] = field(default_factory=list)
    version: int = TABLE_VERSION
    path: Optional[str] = None

    def add(self, entry: dict) -> None:
        """Insert/replace the entry for (kernel, backend, bucket)."""
        key = (entry["kernel"], entry["backend"],
               tuple(sorted(entry["bucket"].items())))
        self.entries = [e for e in self.entries
                        if (e["kernel"], e["backend"],
                            tuple(sorted(e["bucket"].items()))) != key]
        self.entries.append(entry)

    def find(self, kernel: str, backend: str,
             dims: Dict[str, int]) -> Optional[dict]:
        """Exact-bucket match, else nearest dynamic-dim (m/t) match with all
        other bucketed dims equal. None on a full miss (including kernels
        with no bucket schema — nothing is ever tuned for those)."""
        if kernel not in KERNEL_DIMS:
            return None
        want = bucket_dims(kernel, dims)
        cands = [e for e in self.entries
                 if e["kernel"] == kernel and e["backend"] == backend]
        for e in cands:
            if e["bucket"] == want:
                return e
        dyn = _DYNAMIC_DIM.get(kernel)
        if dyn is None or dyn not in want:
            return None
        fixed = {k: v for k, v in want.items() if k != dyn}
        near = [e for e in cands
                if {k: v for k, v in e["bucket"].items() if k != dyn} == fixed]
        if not near:
            return None
        return min(near, key=lambda e: abs(e["bucket"].get(dyn, 0)
                                           - want[dyn]))

    def lookup(self, kernel: str, backend: str,
               dims: Dict[str, int]) -> Optional[Tuple[int, ...]]:
        e = self.find(kernel, backend, dims)
        return tuple(e["tiles"]) if e else None

    # ---- persistence ---------------------------------------------------------
    def to_json(self) -> dict:
        return {"version": self.version, "hw": self.hw,
                "entries": self.entries}

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path or DEFAULT_TABLE_PATH
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        self.path = path
        return path

    @classmethod
    def load(cls, path: str) -> Optional["TuningTable"]:
        """None (with a warning) on a missing/unreadable/version-mismatched
        file — an unusable table must degrade to defaults, never raise."""
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, ValueError) as e:
            warnings.warn(f"tuning table {path!r} unreadable ({e}); "
                          f"falling back to default tiles", stacklevel=2)
            return None
        if raw.get("version") != TABLE_VERSION:
            warnings.warn(f"tuning table {path!r} has version "
                          f"{raw.get('version')!r}, want {TABLE_VERSION}; "
                          f"ignoring it (regenerate with bench_kernels.py)",
                          stacklevel=2)
            return None
        return cls(hw=raw.get("hw", "tpu-v5e"),
                   entries=list(raw.get("entries", [])), path=path)


# ----------------------------------------------------------------------------
# active table + lookup observation
# ----------------------------------------------------------------------------

_UNSET = object()
_ACTIVE: list = [_UNSET]          # _UNSET -> lazily load DEFAULT_TABLE_PATH
_OBSERVER: list = [None]


def active_table() -> Optional[TuningTable]:
    """The table ``tiles='auto'`` resolves from: whatever
    ``set_active_table``/``use_tuning_table`` installed, else the committed
    ``DEFAULT_TABLE_PATH`` (loaded once), else None."""
    if _ACTIVE[0] is _UNSET:
        _ACTIVE[0] = (TuningTable.load(DEFAULT_TABLE_PATH)
                      if os.path.exists(DEFAULT_TABLE_PATH) else None)
    return _ACTIVE[0]


def set_active_table(table: Optional[TuningTable]) -> None:
    """Install ``table`` (None disables auto resolution entirely)."""
    _ACTIVE[0] = table


def reset_active_table() -> None:
    """Forget the installed table; next use lazily reloads the committed
    default."""
    _ACTIVE[0] = _UNSET


@contextlib.contextmanager
def use_tuning_table(table: Optional[TuningTable]):
    prev = _ACTIVE[0]
    _ACTIVE[0] = table
    try:
        yield table
    finally:
        _ACTIVE[0] = prev


@contextlib.contextmanager
def observe_lookups():
    """Record every ``lookup_tiles`` made while the scope is open — trace a
    step under it to learn exactly which (kernel, bucket) entries that step
    consults (the bit-identity test and table-coverage audits use this).
    Yields a list of {kernel, backend, dims, bucket, tiles} dicts."""
    records: List[dict] = []
    prev = _OBSERVER[0]
    _OBSERVER[0] = records
    try:
        yield records
    finally:
        _OBSERVER[0] = prev


def lookup_tiles(kernel: str, backend: str,
                 dims: Dict[str, int]) -> Optional[Tuple[int, ...]]:
    """Tile tuple from the active table, or None (caller falls back to its
    defaults). Every call — hit or miss — is visible to ``observe_lookups``."""
    table = active_table()
    tiles = table.lookup(kernel, backend, dims) if table is not None else None
    if _OBSERVER[0] is not None:
        _OBSERVER[0].append({"kernel": kernel, "backend": backend,
                             "dims": dict(dims),
                             "bucket": bucket_key(kernel, dims),
                             "tiles": tiles})
    return tiles


# ----------------------------------------------------------------------------
# candidate generation + VMEM pruning
# ----------------------------------------------------------------------------

def _divisors_of(n: int, pool: Sequence[int]) -> List[int]:
    return [p for p in pool if p <= n and n % p == 0]


def gmm_candidates(dims: Dict[str, int]) -> List[Tuple[int, int, int]]:
    """(tile_m, tile_k, tile_n) candidates for a gmm measurement shape.
    tile_m is restricted to divisors of the uniform per-group row count
    (the alignment contract); tile_k/tile_n may exceed K/N — the wrapper
    pads — so full-K/full-N single-step configs are always in the pool.
    The plan's 128/512/512 default is always included so "autotuned beats
    default" is decidable from the same run."""
    rows = dims["m"] // max(dims.get("g", 1), 1)
    tms = _divisors_of(rows, (32, 64, 128, 256)) or [rows]
    tks = sorted({min(t, pow2_bucket(dims["k"])) for t in (256, 512, 1024)}
                 | {dims["k"]})
    tns = sorted({min(t, pow2_bucket(dims["n"])) for t in (512, 1024)}
                 | {dims["n"]})
    cands = {(tm, tk, tn) for tm in tms for tk in tks for tn in tns}
    # the plan default, tile_m legalized to the group alignment (a raw
    # 128 on a <128-row group crosses group boundaries = wrong results)
    cands.add(_legalize_gmm(dims, (128, 512, 512)))
    return sorted(cands)


def elementwise_candidates(dims: Dict[str, int]) -> List[Tuple[int, int]]:
    """(tile_rows, tile_cols) candidates for fused_swiglu / combine — both
    tile exact divisors of their dims (no padding in those wrappers)."""
    rows = dims.get("m", dims.get("t"))
    cols = dims.get("n", dims.get("d"))
    tr = _divisors_of(rows, (8, 16, 32, 64, 128, 256)) or [1]
    tc = _divisors_of(cols, (32, 64, 128, 256, 512)) or [1]
    return sorted({(a, b) for a in tr for b in tc})


def prune_candidates(kernel: str, candidates, *, hw=None,
                     in_bytes: int = 2) -> list:
    """Drop candidates whose double-buffered working set exceeds the
    target hardware's fast-memory budget — before anything compiles."""
    from repro.launch.roofline import get_hardware, gmm_working_set_bytes
    hw = get_hardware(hw) if isinstance(hw, str) else \
        (hw or get_hardware("tpu-v5e"))
    kept = []
    for c in candidates:
        if kernel in ("gmm", "tgmm"):
            ws = gmm_working_set_bytes(*c, in_bytes=in_bytes)
        else:    # elementwise: in0 + in1 + out tiles, double-buffered
            ws = 3 * c[0] * c[1] * in_bytes * 2
        if ws <= hw.vmem_bytes:
            kept.append(c)
    return kept


# ----------------------------------------------------------------------------
# measurement
# ----------------------------------------------------------------------------

def _median_time_ms(fn, args, n_iters: int) -> float:
    """bench_epso discipline: explicit warmup (compile + place), then
    median of ``n_iters`` blocked timings."""
    import time

    import jax

    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(n_iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e3


def _gmm_inputs(dims: Dict[str, int]):
    import jax
    import jax.numpy as jnp
    g, m, k, n = dims["g"], dims["m"], dims["k"], dims["n"]
    k0, k1 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k0, (m, k), jnp.bfloat16)
    w = jax.random.normal(k1, (g, k, n), jnp.bfloat16)
    gs = jnp.full((g,), m // g, jnp.int32)
    return x, w, gs


def measure_gmm(dims: Dict[str, int], tiles: Tuple[int, int, int], *,
                n_iters: int = 5, validate: bool = False) -> float:
    """Median ms of one gmm at ``dims`` with an explicit tile triple
    (uniform groups: m/g rows each). ``validate`` checks the candidate
    against the pure-JAX reference once before timing."""
    import jax

    from repro.kernels import ops, ref
    from repro.parallel.plan import KernelPlan, use_kernel_plan

    x, w, gs = _gmm_inputs(dims)
    tm, tk, tn = tiles
    plan = KernelPlan(backend="pallas", tile_m=tm, tile_k=tk, tile_n=tn)
    with use_kernel_plan(plan):
        fn = jax.jit(lambda a, b, c: ops.gmm(a, b, c))
        if validate:
            import numpy as np
            got = np.asarray(fn(x, w, gs), dtype=np.float32)
            want = np.asarray(ref.gmm_ref(x, w, gs), dtype=np.float32)
            np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)
        return _median_time_ms(fn, (x, w, gs), n_iters)


def gmm_flops(dims: Dict[str, int]) -> float:
    return 2.0 * dims["m"] * dims["k"] * dims["n"]


def _legalize_gmm(dims: Dict[str, int],
                  tiles: Tuple[int, ...]) -> Tuple[int, ...]:
    """Clamp a tile triple to something measurable at ``dims``: tile_m must
    divide the uniform per-group row count (the wrapper clamps tile_k/tile_n
    itself). Keeps the plan-default timing well-defined on shapes smaller
    than the default tile_m."""
    rows = dims["m"] // max(dims.get("g", 1), 1)
    tm = tiles[0]
    while rows % tm:
        tm //= 2
    return (max(tm, 1), tiles[1], tiles[2])


def _tgmm_inputs(dims: Dict[str, int]):
    import jax
    import jax.numpy as jnp
    g, m, k, n = dims["g"], dims["m"], dims["k"], dims["n"]
    k0, k1 = jax.random.split(jax.random.PRNGKey(1))
    lhs = jax.random.normal(k0, (m, k), jnp.bfloat16)
    rhs = jax.random.normal(k1, (m, n), jnp.bfloat16)
    gs = jnp.full((g,), m // g, jnp.int32)
    return lhs, rhs, gs


def measure_tgmm(dims: Dict[str, int], tiles: Tuple[int, int, int], *,
                 n_iters: int = 5, validate: bool = False) -> float:
    """Median ms of one tgmm (transposed grouped matmul — the gmm weight
    gradient: out[g] = lhs[rows of g]^T @ rhs[rows of g]) at ``dims`` with
    an explicit tile triple. Mirrors ``ops._gmm_bwd``'s invocation exactly
    (pad K/N, tile->group scalar prefetch, empty-group zero-fill) so the
    table rows that ``_gmm_bwd`` resolves under ``tiles='auto'`` are
    measured on the same program it traces."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref
    from repro.kernels.gmm import tgmm_pallas

    lhs, rhs, gs = _tgmm_inputs(dims)
    G, M, K, N = dims["g"], dims["m"], dims["k"], dims["n"]
    tm, tk, tn = tiles
    tk = min(tk, K)
    tn = min(tn, N)

    def fn(x, dy, group_sizes):
        xp = ops._pad_to(x, tk, 1)
        dyp = ops._pad_to(dy, tn, 1)
        gids = ops._tile_group_ids(group_sizes, M // tm, tm)
        out = tgmm_pallas(xp, dyp, gids, G, tile_m=tm, tile_k=tk,
                          tile_n=tn, interpret=ops._interpret())
        out = jnp.where((group_sizes > 0)[:, None, None], out, 0)
        return out[:, :K, :N]

    jitted = jax.jit(fn)
    if validate:
        import numpy as np
        got = np.asarray(jitted(lhs, rhs, gs), dtype=np.float32)
        want = np.asarray(ref.tgmm_ref(lhs, rhs, gs, G), dtype=np.float32)
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)
    return _median_time_ms(jitted, (lhs, rhs, gs), n_iters)


def tgmm_flops(dims: Dict[str, int]) -> float:
    # sum_g rows_g x K x N multiply-adds == M x K x N total
    return 2.0 * dims["m"] * dims["k"] * dims["n"]


def tgmm_candidates(dims: Dict[str, int]) -> List[Tuple[int, int, int]]:
    """(tile_m, tile_k, tile_n) candidates for a tgmm measurement shape:
    tile_m under the same group-alignment contract as gmm (it tiles the
    shared row dim); tile_k/tile_n tile the *output* (G, K, N) block and
    may exceed K/N (the wrapper pads). ``_gmm_bwd``'s built-in 512/512
    defaults are always included so parity is decidable in-run."""
    rows = dims["m"] // max(dims.get("g", 1), 1)
    tms = _divisors_of(rows, (32, 64, 128, 256)) or [rows]
    tks = sorted({min(t, pow2_bucket(dims["k"])) for t in (256, 512)}
                 | {dims["k"]})
    tns = sorted({min(t, pow2_bucket(dims["n"])) for t in (256, 512)}
                 | {dims["n"]})
    cands = {(tm, tk, tn) for tm in tms for tk in tks for tn in tns}
    cands.add(_legalize_gmm(dims, (128, 512, 512)))
    return sorted(cands)


_MEASURE = {"gmm": measure_gmm, "tgmm": measure_tgmm}
_CANDIDATES = {"gmm": gmm_candidates, "tgmm": tgmm_candidates}
_FLOPS = {"gmm": gmm_flops, "tgmm": tgmm_flops}
_LEGALIZE = {"gmm": _legalize_gmm, "tgmm": _legalize_gmm}


def autotune(kernel: str, shapes: Sequence[Dict[str, int]],
             candidates=None, *, backend: str = "pallas", n_iters: int = 5,
             hw: str = "tpu-v5e", measured_hw: Optional[object] = None,
             validate: bool = True, table: Optional[TuningTable] = None,
             default_tiles: Tuple[int, ...] = (128, 512, 512),
             log=None) -> TuningTable:
    """Measured tile search over ``shapes`` (dim dicts, e.g.
    ``{"g": 2, "m": 256, "k": 512, "n": 1792}`` for gmm).

    For each shape: generate candidates (or use ``candidates``), prune
    against ``hw``'s VMEM budget analytically, time each survivor
    (median-of-``n_iters``), and record the winner next to the
    ``default_tiles`` timing in ``table``. ``measured_hw`` (a HardwareSpec,
    e.g. ``calibrate_sim_cpu()``) stamps the achieved-vs-peak fraction.
    Returns the (new or updated) table.
    """
    if kernel not in _MEASURE:
        raise ValueError(f"no measurement adapter for kernel {kernel!r} "
                         f"(have: {', '.join(sorted(_MEASURE))})")
    table = table if table is not None else TuningTable(hw=hw)
    measure = _MEASURE[kernel]
    for dims in shapes:
        cands = list(candidates) if candidates is not None \
            else _CANDIDATES[kernel](dims)
        kept = prune_candidates(kernel, cands, hw=hw)
        if log:
            log(f"{kernel} {bucket_key(kernel, dims)}: "
                f"{len(cands)} candidates, {len(kept)} after VMEM prune")
        results = []
        for c in kept:
            try:
                t = measure(dims, c, n_iters=n_iters, validate=validate)
            except Exception as e:        # invalid config: skip, keep going
                if log:
                    log(f"  {c}: skipped ({type(e).__name__}: {e})")
                continue
            results.append((t, c))
            if log:
                log(f"  {c}: {t:.1f}ms")
        if not results:
            continue
        best_t, best_c = min(results, key=lambda r: r[0])
        legalize = _LEGALIZE.get(kernel, lambda d, t: tuple(t))
        dflt = tuple(legalize(dims, tuple(default_tiles)))
        dflt_t = dict((tuple(c), t) for t, c in results).get(dflt)
        if dflt_t is None:
            dflt_t = measure(dims, dflt, n_iters=n_iters, validate=False)
        entry = {
            "kernel": kernel, "backend": backend,
            "bucket": bucket_dims(kernel, dims), "shape": dict(dims),
            "tiles": list(best_c), "time_ms": best_t,
            "default_tiles": list(dflt), "default_time_ms": dflt_t,
            "n_iters": n_iters, "hw": hw,
        }
        flops = _FLOPS.get(kernel)
        if flops:
            gf = flops(dims) / 1e9
            entry["gflops"] = gf
            if measured_hw is not None:
                entry["measured_hw"] = measured_hw.name
                entry["achieved_frac"] = (gf * 1e9 / (best_t / 1e3)
                                          / measured_hw.peak_flops)
        table.add(entry)
    return table
