"""Pallas TPU token-counting kernel (paper §3.1 Stage 2).

The paper's GPU kernel maps threads to row blocks of the routing-indices
tensor and bumps per-(expert, thread) counters with atomics, then reduces.
TPU has no atomics; the adaptation processes the flattened indices in grid
tiles, forms a one-hot (tile × experts) matrix in VMEM, row-reduces it and
accumulates into the (experts,) output block — the output block is revisited
by every grid step (index map is constant), which Pallas TPU supports for
sequential grids. The partial-counts-then-reduce structure of the paper
becomes the grid-step accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _count_kernel(idx_ref, out_ref, *, num_local: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...]                                   # (1, TB) int32
    eye = jax.lax.broadcasted_iota(jnp.int32, (num_local, idx.shape[1]), 0)
    onehot = (idx == eye).astype(jnp.int32)              # (E, TB)
    out_ref[...] += onehot.sum(axis=1)


def token_counts_pallas(indices: jax.Array, num_local: int, offset, *,
                        tile: int = 1024, interpret: bool = False) -> jax.Array:
    """indices: (F,) flat global expert ids; returns (num_local,) int32
    counts of ids in [offset, offset + num_local)."""
    F = indices.shape[0]
    tb = min(tile, F)
    pad = (-F) % tb
    local = indices.astype(jnp.int32) - offset
    local = jnp.where((local >= 0) & (local < num_local), local, num_local)
    local = jnp.pad(local, (0, pad), constant_values=num_local)
    local = local.reshape(1, F + pad)                    # 2-D for TPU layout

    return pl.pallas_call(
        functools.partial(_count_kernel, num_local=num_local),
        grid=((F + pad) // tb,),
        in_specs=[pl.BlockSpec((1, tb), lambda t: (0, t))],
        out_specs=pl.BlockSpec((num_local,), lambda t: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_local,), jnp.int32),
        interpret=interpret,
    )(local)
