"""Pallas TPU kernels for the compute hot-spots (DESIGN §2, §3).

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
a jit'd wrapper (+custom VJP where trained) in ops.py, and a pure-jnp
oracle in ref.py; all validated on CPU via interpret=True with
shape/dtype sweeps (tests/test_kernels.py, test_flash_attention.py,
test_ssd_kernel.py).

  gmm.py              Stage-4 grouped matmul (ragged, scalar-prefetched
                      tile->group map) + tgmm weight-gradient kernel
  moe_dispatch.py     Stage-2 token-count histogram
  combine.py          Stage-5 output reduction, forward + fused backward
  swiglu.py           fused SwiGLU activation
  flash_attention.py  blockwise online-softmax attention (causal + SWA)
  ssd.py              Mamba-2 SSD intra-chunk stage (hybrid archs)
"""
