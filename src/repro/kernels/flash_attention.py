"""Pallas TPU flash attention (forward): blockwise online-softmax attention
with causal and sliding-window masking.

Grid (batch·heads, q_blocks, kv_blocks), kv innermost; the (m, l, acc)
online-softmax state lives in VMEM scratch and persists across the kv sweep
(the output block is revisited consecutively — the sequential-grid pattern
Pallas TPU guarantees). VMEM per step: qb·hd + kb·hd (bf16) + qb·(hd+2) f32
≈ 0.4 MiB at (512, 128) tiles — ample room for double buffering.

This is the TPU-native replacement for the pure-JAX blockwise attention in
repro/models/layers.py (same math — that function doubles as the oracle
harness; ref.py holds the dense reference).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, qb: int, kb: int,
                  n_k: int, sq: int, skv: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (qb, hd)
    k = k_ref[0].astype(jnp.float32)                    # (kb, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

    q_pos = i * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    k_pos = j * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    mask = k_pos < skv                                  # kv padding
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           q_block: int = 512, kv_block: int = 512,
                           interpret: bool = False):
    """q: (BH, Sq, hd); k/v: (BH, Skv, hd) — heads pre-flattened (GQA kv
    heads pre-broadcast). Returns (BH, Sq, hd)."""
    from jax.experimental.pallas import tpu as pltpu
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    qb, kb = min(q_block, Sq), min(kv_block, Skv)
    pq, pk = (-Sq) % qb, (-Skv) % kb
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    n_q, n_k = (Sq + pq) // qb, (Skv + pk) // kb

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=1.0 / math.sqrt(hd),
                          causal=causal, window=window, qb=qb, kb=kb,
                          n_k=n_k, sq=Sq, skv=Skv),
        grid=(BH, n_q, n_k),
        in_specs=[pl.BlockSpec((1, qb, hd), lambda b, i, j: (b, i, 0)),
                  pl.BlockSpec((1, kb, hd), lambda b, i, j: (b, j, 0)),
                  pl.BlockSpec((1, kb, hd), lambda b, i, j: (b, j, 0))],
        out_specs=pl.BlockSpec((1, qb, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq + pq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((qb,), jnp.float32),
                        pltpu.VMEM((qb,), jnp.float32),
                        pltpu.VMEM((qb, hd), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Sq]
