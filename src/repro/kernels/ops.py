"""Public jit'd wrappers for the Pallas kernels, with custom VJPs.

* ``gmm(x, w, group_sizes)``      — Stage 4 grouped matmul. Backward:
      dx = gmm(dy, swap(w)),  dw = tgmm(x, dy)  (both Pallas kernels).
* ``combine(rows, weights)``      — Stage 5 output reduction; backward uses
      the paper's fused backward kernel.
* ``fused_swiglu(gate, up)``      — fused activation; analytic VJP.
* ``token_counts(idx, n, off)``   — Stage 2 histogram (no gradient).

Tile sizes (MXU-aligned 128/512 defaults) and the interpret flag (True on
CPU: kernels execute their Python bodies — how this container validates TPU
kernels) come from the *active* ``parallel.plan.KernelPlan`` — plan-scoped
via ``use_kernel_plan`` (leak-free), read at trace time. Under
``KernelPlan(tiles='auto')`` each wrapper first consults the measured
tuning table (kernels/autotune.py) for its shape bucket and falls back to
the plan's explicit tiles on a miss.
Wrappers pad K/N dims up to tile multiples (zero-padding is exact for
matmul) and slice back.

Tombstone: the PR 4 dict-view compatibility alias over the process-default
plan is deleted (lint rule SL004 forbids the symbol repo-wide). Scope a
plan with ``use_kernel_plan`` / set the process default with
``set_default_kernel_plan`` instead.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.parallel.plan import (KernelPlan, current_kernel_plan,
                                 default_kernel_plan,
                                 set_default_kernel_plan, use_kernel_plan)

from .gmm import gmm_pallas, tgmm_pallas
from .combine import combine_fwd_pallas, combine_bwd_pallas
from .swiglu import swiglu_pallas
from .moe_dispatch import token_counts_pallas

__all__ = ["KernelPlan", "current_kernel_plan", "default_kernel_plan",
           "set_default_kernel_plan", "use_kernel_plan",
           "gmm", "combine", "fused_swiglu", "token_counts",
           "flash_attention", "gmm_align", "ssd_intra_chunk"]


def _interpret() -> bool:
    flag = current_kernel_plan().interpret
    if flag is None:
        return jax.default_backend() == "cpu"
    return bool(flag)


def gmm_align() -> int:
    """Group alignment the dispatch must honor for the Pallas backend."""
    return current_kernel_plan().tile_m


def _pad_to(x, mult, axis):
    r = (-x.shape[axis]) % mult
    if r == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, r)
    return jnp.pad(x, pads)


def _tile_group_ids(group_sizes: jax.Array, n_tiles: int, tile_m: int):
    """tile -> group map (scalar prefetch). Requires group_sizes % tile_m == 0
    (ensured by the dispatch's alignment). Tiles past sum(group_sizes) are
    clamped to the last group; their rows are masked out by the callers."""
    G = group_sizes.shape[0]
    offsets = jnp.cumsum(group_sizes)
    tile_starts = jnp.arange(n_tiles, dtype=jnp.int32) * tile_m
    gids = jnp.searchsorted(offsets, tile_starts, side="right")
    return jnp.minimum(gids, G - 1).astype(jnp.int32)


# ----------------------------------------------------------------------------
# gmm with custom VJP
# ----------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=())
def gmm(x: jax.Array, w: jax.Array, group_sizes: jax.Array) -> jax.Array:
    return _gmm_fwd_impl(x, w, group_sizes)


def _resolved_gmm_tiles(kp, G, M, K, N):
    """Plan tiles, overridden by the tuning table under ``tiles='auto'``.
    An auto tile_m only applies when it divides the plan's tile_m (the
    dispatch pads group sizes to ``plan.tile_m``, so any divisor keeps the
    ``group_sizes % tile_m == 0`` kernel contract) and divides M."""
    tm, tk, tn = kp.tile_m, kp.tile_k, kp.tile_n
    auto = kp.resolve_tiles("gmm", {"g": G, "m": M, "k": K, "n": N})
    if auto is not None:
        atm, atk, atn = auto
        if atm and kp.tile_m % atm == 0 and M % atm == 0:
            tm = atm
        tk = atk or tk
        tn = atn or tn
    return tm, tk, tn


def _gmm_fwd_impl(x, w, group_sizes):
    kp = current_kernel_plan()
    M, K = x.shape
    G, _, N = w.shape
    tm, tk, tn = _resolved_gmm_tiles(kp, G, M, K, N)
    tk = min(tk, K)
    tn = min(tn, N)
    xp = _pad_to(x, tk, 1)
    wp = _pad_to(_pad_to(w, tk, 1), tn, 2)
    n_tiles = M // tm
    gids = _tile_group_ids(group_sizes, n_tiles, tm)
    out = gmm_pallas(xp, wp, gids, tile_m=tm, tile_k=tk, tile_n=tn,
                     interpret=_interpret())
    # rows past sum(group_sizes) belong to no group -> zero (ref semantics)
    total = jnp.sum(group_sizes)
    out = out * (jnp.arange(M) < total)[:, None].astype(out.dtype)
    return out[:, :N]


def _gmm_fwd(x, w, group_sizes):
    return _gmm_fwd_impl(x, w, group_sizes), (x, w, group_sizes)


def _gmm_bwd(res, dy):
    x, w, group_sizes = res
    kp = current_kernel_plan()
    M, K = x.shape
    G, _, N = w.shape
    # dx = gmm(dy, w^T) — resolves its own (k=N, n=K) bucket under 'auto'
    dx = _gmm_fwd_impl(dy, jnp.swapaxes(w, 1, 2), group_sizes)
    # dw[g] = x_g^T dy_g  (tgmm kernel: lhs = x (M,K), rhs = dy (M,N)
    # -> out (G,K,N)); tile defaults 512/512, table-overridable
    tm = kp.tile_m
    tkk = min(512, K)
    tnn = min(512, N)
    auto = kp.resolve_tiles("tgmm", {"g": G, "m": M, "k": K, "n": N})
    if auto is not None:
        atm, atk, atn = auto
        if atm and kp.tile_m % atm == 0 and M % atm == 0:
            tm = atm
        tkk = min(atk or tkk, K)
        tnn = min(atn or tnn, N)
    total = jnp.sum(group_sizes)
    row_mask = (jnp.arange(M) < total)[:, None]
    xp = _pad_to(x * row_mask.astype(x.dtype), tkk, 1)
    dyp = _pad_to(dy * row_mask.astype(dy.dtype), tnn, 1)
    gids = _tile_group_ids(group_sizes, M // tm, tm)
    dw = tgmm_pallas(xp, dyp, gids, G, tile_m=tm, tile_k=tkk, tile_n=tnn,
                     interpret=_interpret())
    # groups with zero rows have no tiles -> their output block is never
    # written (uninitialized); their true gradient is zero.
    dw = jnp.where((group_sizes > 0)[:, None, None], dw, 0)
    dw = dw[:, :K, :N].astype(w.dtype)
    return dx.astype(x.dtype), dw, None


gmm.defvjp(_gmm_fwd, _gmm_bwd)


# ----------------------------------------------------------------------------
# combine with the paper's fused backward kernel
# ----------------------------------------------------------------------------

@jax.custom_vjp
def combine(rows: jax.Array, weights: jax.Array) -> jax.Array:
    return _combine_fwd_impl(rows, weights)


def _tile_t(T):
    for t in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if T % t == 0:
            return t
    return 1


def _tile_d(D):
    for t in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if D % t == 0:
            return t
    return 1


def _combine_tiles(T, K, D):
    """Divisor-scan defaults, overridden by the tuning table under
    ``tiles='auto'`` when the table tiles divide the actual dims (these
    wrappers don't pad, so non-divisors fall back)."""
    tt, td = _tile_t(T), _tile_d(D)
    auto = current_kernel_plan().resolve_tiles(
        "combine", {"t": T, "k": K, "d": D})
    if auto is not None:
        at, ad = auto
        if at and T % at == 0:
            tt = at
        if ad and D % ad == 0:
            td = ad
    return tt, td


def _combine_fwd_impl(rows, weights):
    T, K, D = rows.shape
    tt, td = _combine_tiles(T, K, D)
    return combine_fwd_pallas(rows, weights, tile_t=tt, tile_d=td,
                              interpret=_interpret())


def _combine_fwd(rows, weights):
    return _combine_fwd_impl(rows, weights), (rows, weights)


def _combine_bwd(res, dout):
    rows, weights = res
    T, K, D = rows.shape
    tt, td = _combine_tiles(T, K, D)
    drows, dw = combine_bwd_pallas(rows, weights, dout, tile_t=tt,
                                   tile_d=td, interpret=_interpret())
    return drows, dw.astype(weights.dtype)


combine.defvjp(_combine_fwd, _combine_bwd)


# ----------------------------------------------------------------------------
# fused swiglu
# ----------------------------------------------------------------------------

@jax.custom_vjp
def fused_swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return _swiglu_impl(gate, up)


def _swiglu_impl(gate, up):
    M, N = gate.shape
    tm, tn = _tile_t(M), _tile_d(N)
    auto = current_kernel_plan().resolve_tiles(
        "fused_swiglu", {"m": M, "n": N})
    if auto is not None:
        am, an = auto
        if am and M % am == 0:
            tm = am
        if an and N % an == 0:
            tn = an
    return swiglu_pallas(gate, up, tile_m=tm, tile_n=tn,
                         interpret=_interpret())


def _swiglu_fwd(gate, up):
    return _swiglu_impl(gate, up), (gate, up)


def _swiglu_bwd(res, dout):
    gate, up = res
    g = gate.astype(jnp.float32)
    sig = jax.lax.logistic(g)
    silu = g * sig
    dsilu = sig * (1 + g * (1 - sig))
    dout32 = dout.astype(jnp.float32)
    dgate = (dout32 * up.astype(jnp.float32) * dsilu).astype(gate.dtype)
    dup = (dout32 * silu).astype(up.dtype)
    return dgate, dup


fused_swiglu.defvjp(_swiglu_fwd, _swiglu_bwd)


# ----------------------------------------------------------------------------
# token counts (Stage 2) — integer output, no gradient
# ----------------------------------------------------------------------------

def token_counts(indices: jax.Array, num_local: int, offset) -> jax.Array:
    return token_counts_pallas(indices, num_local, offset,
                               interpret=_interpret())


# ----------------------------------------------------------------------------
# flash attention (forward; training uses the pure-JAX blockwise path)
# ----------------------------------------------------------------------------

def ssd_intra_chunk(x, dt, Bm, Cm, A):
    """Mamba-2 SSD intra-chunk stage (see kernels/ssd.py)."""
    from .ssd import ssd_intra_chunk_pallas
    return ssd_intra_chunk_pallas(x, dt, Bm, Cm, A, interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_block: int = 512, kv_block: int = 512) -> jax.Array:
    """q: (B, Sq, nh, hd); k/v: (B, Skv, nkv, hd). GQA kv heads are
    broadcast to nh; heads fold into the batch for the kernel."""
    from .flash_attention import flash_attention_pallas
    B, Sq, nh, hd = q.shape
    Skv, nkv = k.shape[1], k.shape[2]
    if nkv != nh:
        rep = nh // nkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(B * nh, a.shape[1], hd)
    out = flash_attention_pallas(fold(q), fold(k), fold(v), causal=causal,
                                 window=window, q_block=q_block,
                                 kv_block=kv_block, interpret=_interpret())
    return out.reshape(B, nh, Sq, hd).transpose(0, 2, 1, 3)
