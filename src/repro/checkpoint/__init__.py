from .checkpointer import (Checkpointer, dp_scattered_writers,
                           save_pytree, load_pytree)
