"""Checkpointing substrate (paper §4).

* **Dual checkpointing** — two full-checkpoint slots (ckpt-1 / ckpt-2),
  alternating by age; a failure mid-write never destroys the only valid
  checkpoint. Writes are atomic (tmp dir + rename) and a MANIFEST with step
  + leaf checksums marks validity.
* **Persistent model-only checkpointing** — parameters only (8x smaller
  than a full AdamW checkpoint in bf16 mixed precision), kept at every
  interval (never rotated) so training can be tracked back to a good regime
  after divergence; restoring one reinitializes optimizer states.
* **DP-scattered model checkpointing** — model-parallel shard m is written
  by DP rank (m % DP), spreading filesystem load across nodes instead of
  concentrating all writes on dp_index 0 (``dp_scattered_writers``).
* **Model broadcasting** — in multi-host deployments only one rank loads
  from the filesystem and broadcasts (paper uses torch.broadcast/all_reduce);
  single-process JAX gets this for free via ``jax.device_put`` replication,
  recorded here as ``broadcast_params`` for API parity.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time

import jax
import numpy as np


# ---------------------------------------------------------------------------
# pytree <-> flat npz
# ---------------------------------------------------------------------------

def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(tree, path: str):
    np.savez(path, **_flatten(tree))


def load_pytree(template, path: str):
    data = np.load(path)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    new = []
    for p, leaf in leaves:
        key = jax.tree_util.keystr(p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), f"{key}: {arr.shape}"
        new.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), new)


def _checksum(d: dict) -> str:
    h = hashlib.sha256()
    for k in sorted(d):
        h.update(k.encode())
        h.update(np.ascontiguousarray(d[k]).tobytes()[:4096])
    return h.hexdigest()[:16]


# ---------------------------------------------------------------------------
# DP-scattered write assignment
# ---------------------------------------------------------------------------

def dp_scattered_writers(num_model_shards: int, dp_size: int) -> dict:
    """shard m -> writing DP rank (paper: d = m % DP)."""
    return {m: m % dp_size for m in range(num_model_shards)}


def broadcast_params(params, mesh=None):
    """Load-once-broadcast (paper §4 'Model Broadcasting'). In single-process
    JAX, placing the host array on a replicated sharding performs exactly one
    host->devices broadcast rather than per-rank filesystem loads."""
    if mesh is None:
        return params
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.tree.map(
        lambda a: jax.device_put(a, NamedSharding(mesh, P())), params)


# ---------------------------------------------------------------------------
# Checkpointer
# ---------------------------------------------------------------------------

class Checkpointer:
    """Dual + model-only checkpointing; mesh-sharded states are supported by
    gather-on-save (``np.asarray`` on a single-process sharded jax.Array
    assembles the global array) and reshard-on-restore (restored host arrays
    are ``device_put`` back onto ``shardings``, so an SO/EPSO run resumes
    with the exact placement it was jitted for). This covers the pipeline
    stage axis too: a pp-stage-sharded layer stack is gathered into one
    stage-agnostic (L, ...) array on disk and resharded back onto its
    P('pp', ...) placement on restore, so checkpoints are portable across
    pipeline layouts.

    With a ``plan`` (a resolved ParallelPlan), its spec + axis layout are
    serialized into each MANIFEST; ``restore`` then *refuses* to silently
    reshard a checkpoint written under a different axis layout — it raises
    a descriptive error unless the caller opts in with
    ``on_plan_mismatch='reshard'`` (an explicit re-plan: the host arrays are
    device_put onto the live plan's shardings).

    Live expert placement (parallel/placement.py): under EP rebalancing the
    expert stacks are saved in their *placed* order, and the live
    ``placement`` (kept current by the launcher) rides in the MANIFEST —
    ``restore`` surfaces it as ``restored_placement`` so the caller rebuilds
    the step against the exact placement the arrays were written under
    (resume bit-identical mid-rebalance-schedule). Placement does not change
    shardings, so ``layout_signature`` plan checks are orthogonal."""

    def __init__(self, root: str, *, interval: int = 1000,
                 model_only_interval: int = 0, shardings=None,
                 plan=None, on_plan_mismatch: str = "error",
                 placement=None):
        if on_plan_mismatch not in ("error", "reshard"):
            raise ValueError("on_plan_mismatch must be 'error' or 'reshard',"
                             f" got {on_plan_mismatch!r}")
        self.root = root
        self.interval = interval
        self.model_only_interval = model_only_interval or interval
        self.shardings = shardings       # state-shaped pytree or None
        self.plan = plan                 # ResolvedPlan or None
        self.on_plan_mismatch = on_plan_mismatch
        self.placement = placement       # live ExpertPlacement or None
        self.restored_placement = None   # set by restore()
        os.makedirs(root, exist_ok=True)
        self.slots = [os.path.join(root, "ckpt-1"),
                      os.path.join(root, "ckpt-2")]

    # ---- dual full checkpoints -------------------------------------------
    def _slot_manifest(self, slot: str):
        man = os.path.join(slot, "MANIFEST.json")
        if not os.path.exists(man):
            return None
        try:
            with open(man) as f:
                return json.load(f)
        except Exception:
            return None

    def _slot_step(self, slot: str) -> int:
        m = self._slot_manifest(slot)
        if m is None:
            return -1
        try:
            return int(m["step"]) if m.get("valid") else -1
        except Exception:
            return -1

    def _oldest_slot(self) -> str:
        steps = [self._slot_step(s) for s in self.slots]
        return self.slots[int(np.argmin(steps))]

    def save(self, state, step: int, *, fail_after_write: bool = False):
        """Write a full checkpoint into the *older* of the two slots.
        ``fail_after_write`` simulates a mid-checkpoint failure (tests)."""
        slot = self._oldest_slot()
        tmp = slot + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(state)
        np.savez(os.path.join(tmp, "state.npz"), **flat)
        if fail_after_write:      # crash before the manifest => slot invalid
            if os.path.exists(slot):
                shutil.rmtree(slot)
            os.rename(tmp, slot)
            return slot
        man = {"step": step, "valid": True, "time": time.time(),
               "checksum": _checksum(flat)}
        if self.plan is not None:
            man["plan"] = {"spec": self.plan.spec(),
                           "layout": self.plan.layout_signature()}
        if self.placement is not None:
            man["placement"] = self.placement.to_manifest()
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(man, f)
        if os.path.exists(slot):
            shutil.rmtree(slot)
        os.rename(tmp, slot)
        return slot

    def restore(self, template, *, shardings=None):
        """Restore from the newest *valid* slot, resharding each leaf onto
        ``shardings`` (falling back to the instance default) when given.
        Returns (state, step) or (None, -1).

        When both the manifest and this Checkpointer carry a plan, their
        axis layouts must agree — a mismatch raises instead of silently
        resharding onto whatever the caller passed (set
        ``on_plan_mismatch='reshard'`` to re-plan explicitly)."""
        self.restored_placement = None
        best, best_step = None, -1
        for slot in self.slots:
            s = self._slot_step(slot)
            if s > best_step:
                best, best_step = slot, s
        if best is None:
            return None, -1
        manifest = self._slot_manifest(best)
        self._check_plan(manifest, best)
        if (manifest or {}).get("placement") is not None:
            from repro.parallel.placement import ExpertPlacement
            self.restored_placement = ExpertPlacement.from_manifest(
                manifest["placement"])
        else:
            self.restored_placement = None
        state = load_pytree(template, os.path.join(best, "state.npz"))
        sh = shardings if shardings is not None else self.shardings
        if sh is not None:
            state = jax.tree.map(jax.device_put, state, sh)
        return state, best_step

    def _check_plan(self, manifest, slot: str) -> None:
        saved = (manifest or {}).get("plan")
        if saved is None or self.plan is None:
            return                       # legacy checkpoint or legacy caller
        live = {"spec": self.plan.spec(),
                "layout": self.plan.layout_signature()}
        if saved["layout"] == live["layout"]:
            return
        if self.on_plan_mismatch == "reshard":
            print(f"checkpoint {slot}: re-planning "
                  f"'{saved.get('spec')}' -> '{live['spec']}' "
                  f"(explicit on_plan_mismatch='reshard')")
            return
        raise ValueError(
            f"checkpoint {slot} was written under plan "
            f"'{saved.get('spec')}' (layout {saved['layout']}) but this run "
            f"is planned as '{live['spec']}' (layout {live['layout']}); "
            f"refusing to silently reshard — restart with the saved plan, "
            f"or pass on_plan_mismatch='reshard' to re-plan explicitly")

    # ---- persistent model-only checkpoints --------------------------------
    def save_model_only(self, params, step: int):
        path = os.path.join(self.root, f"model-{step:08d}.npz")
        save_pytree(params, path)
        return path

    def list_model_only(self):
        return sorted(f for f in os.listdir(self.root)
                      if f.startswith("model-") and f.endswith(".npz"))

    def restore_model_only(self, template, step: int):
        """Params from the model-only checkpoint at ``step``; the caller
        reinitializes optimizer states (paper: 'training can be restarted
        from just the model parameters')."""
        path = os.path.join(self.root, f"model-{step:08d}.npz")
        return load_pytree(template, path)

    # ---- hooks --------------------------------------------------------------
    def maybe_save(self, state, params, step: int):
        wrote = []
        if step > 0 and step % self.interval == 0:
            wrote.append(self.save(state, step))
        if step > 0 and step % self.model_only_interval == 0:
            wrote.append(self.save_model_only(params, step))
        return wrote
