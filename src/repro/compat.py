"""Version-compat shims for the installed jax.

The codebase (and its tests) target the modern ``jax.sharding`` surface:

* ``jax.sharding.AxisType`` — the Auto/Explicit/Manual axis-type enum,
* ``AbstractMesh(axis_sizes, axis_names, axis_types=...)`` — the
  two-sequence constructor,
* ``jax.make_mesh(..., axis_types=...)`` — the axis-types keyword,
* ``jax.shard_map(..., axis_names=...)`` — top-level shard_map whose
  ``axis_names`` picks the manual axes.

Older jax (0.4.x, the baked-in toolchain on some containers) predates all
three: there is no public ``AxisType``, ``AbstractMesh`` takes a single
``shape_tuple`` of ``(name, size)`` pairs, and ``make_mesh`` rejects
``axis_types``. ``install()`` patches the gap *in the old-jax direction
only* — on a modern jax it is a no-op — so the same source runs on both.
Importing this module installs the shims; ``from repro.compat import
AxisType`` is the canonical spelling inside the repo.
"""
from __future__ import annotations

import enum
import functools

import jax
import jax.sharding as _sharding

try:  # jax >= 0.5: the real enum exists — everything below is a no-op.
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    _NEEDS_SHIM = False
except ImportError:
    _NEEDS_SHIM = True

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stand-in for ``jax.sharding.AxisType`` (accepted and ignored —
        old jax has no user-visible axis-type machinery)."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def _new_style_abstract_mesh(cls):
    """Adapt new-signature calls onto the old single-argument constructor."""

    @functools.wraps(cls, updated=())
    def make(axis_sizes, axis_names=None, *, axis_types=None):
        if axis_names is None:          # old-style: already a shape_tuple
            return cls(axis_sizes)
        return cls(tuple(zip(axis_names, axis_sizes)))

    return make


def _tolerant_make_mesh(fn):
    @functools.wraps(fn)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
        return fn(axis_shapes, axis_names, **kw)

    return make_mesh


def _shard_map_compat(f, *, mesh, in_specs, out_specs, axis_names=None,
                      **kw):
    """``jax.shard_map(..., axis_names={...})`` on top of the experimental
    shard_map, whose equivalent knob is the complement set ``auto``. The
    old static replication checker rejects psum/pmean patterns the modern
    one accepts, so it is off by default (semantics are unchanged; the
    equivalence tests in tests/test_distributed.py are the real check)."""
    from jax.experimental.shard_map import shard_map as _sm
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    kw.setdefault("check_rep", False)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def manual_shard_map(f, mesh, in_specs, out_specs):
    """A fully-manual shard_map (every mesh axis manual, replication checking
    off) across jax vintages. The overlapped optimizer update (optim/overlap)
    emits all-gathered values under replicated out_specs — valid by
    construction, but the static checkers (0.4.x ``check_rep``, newer
    ``check_vma``) cannot always prove it, so both are disabled; the golden
    overlapped-vs-eager parity tests are the real check. Kwarg spelling is
    probed per vintage (``check_vma`` on modern jax, ``check_rep`` on the
    0.4.x experimental shard_map behind the ``jax.shard_map`` shim)."""
    for kw in ({"check_vma": False}, {"check_rep": False}, {}):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kw)
        except TypeError:
            continue
    raise RuntimeError("no usable jax.shard_map signature found")


def install() -> None:
    """Idempotently install the shims into ``jax`` / ``jax.sharding``.
    The two probes are independent: mid-vintage jax has ``AxisType`` but
    not yet the top-level ``jax.shard_map`` alias."""
    if _NEEDS_SHIM and getattr(_sharding, "AxisType", None) is not AxisType:
        _sharding.AxisType = AxisType
        _sharding.AbstractMesh = _new_style_abstract_mesh(
            _sharding.AbstractMesh)
        jax.make_mesh = _tolerant_make_mesh(jax.make_mesh)
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map_compat


install()
