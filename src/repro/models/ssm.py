"""State-space blocks: Mamba-1 (selective scan) and Mamba-2 (chunked SSD).

TPU adaptation note (DESIGN §3): Mamba-2 uses the chunked SSD formulation —
intra-chunk work is dense matmuls (MXU-friendly) and only the inter-chunk
state recurrence is a short ``lax.scan``. Mamba-1 keeps the classic
selective scan (``lax.scan`` over time) as its reference semantics.

Both provide a train/prefill path over (B, S, d) and an O(1)-state
single-token decode step (the reason SSM archs run the long_500k shape).
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name


# ----------------------------------------------------------------------------
# causal depthwise conv1d
# ----------------------------------------------------------------------------

def causal_conv1d(x, w, b):
    """x: (B, S, C); w: (C, K) depthwise; left-padded causal."""
    K = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(K):  # K is tiny (4); unrolled taps beat a conv call on TPU
        out = out + xp[:, j:j + x.shape[1]] * w[:, j]
    return out + b


def conv_step(state, x_t, w, b):
    """state: (B, K-1, C) previous inputs; x_t: (B, C). Returns (new_state, y)."""
    window = jnp.concatenate([state, x_t[:, None]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,ck->bc", window, w) + b
    return window[:, 1:], y


# ----------------------------------------------------------------------------
# Mamba-1
# ----------------------------------------------------------------------------

def mamba1_dims(cfg):
    d = cfg.d_model
    di = cfg.ssm.expand * d
    dt_rank = max(1, d // 16)
    return d, di, dt_rank, cfg.ssm.d_state, cfg.ssm.d_conv


def init_mamba1(rng, cfg) -> dict:
    d, di, dt_rank, ds, K = mamba1_dims(cfg)
    ks = jax.random.split(rng, 6)
    s = 1.0 / math.sqrt(d)
    si = 1.0 / math.sqrt(di)
    dt_init = jnp.exp(jax.random.uniform(ks[4], (di,)) *
                      (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    inv_softplus = jnp.log(jnp.expm1(dt_init))
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), jnp.float32) * s,
        "conv_w": jax.random.normal(ks[1], (di, K), jnp.float32) * 0.5,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": jax.random.normal(ks[2], (di, dt_rank + 2 * ds), jnp.float32) * si,
        "dt_proj": jax.random.normal(ks[3], (dt_rank, di), jnp.float32)
                   * (dt_rank ** -0.5),
        "dt_bias": inv_softplus,
        "A_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[5], (di, d), jnp.float32) * si,
    }


def _mamba1_inner(p, xc, z, cfg, h0=None):
    """xc: post-conv activation (B,S,di); returns (y (B,S,di), h_last).

    Memory-optimized formulation (EXPERIMENTS §Perf, falcon-mamba hillclimb):
    the decay exp(dt*A) and input injection dt*x*B are computed *inside* the
    scan body from the small (B,S,di)/(B,S,ds) streams instead of
    materializing two (B,S,di,ds) tensors in HBM — the structure of a fused
    selective-scan kernel, where only the per-step state (B,di,ds) lives
    on-chip and the streams are read once. (The backward still stores the
    state trajectory — accounted analytically in launch/costmodel.py.)
    """
    _, di, dt_rank, ds, _ = mamba1_dims(cfg)
    B, S, _ = xc.shape
    proj = xc @ p["x_proj"].astype(xc.dtype)
    dt, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(xc.dtype)
                         + p["dt_bias"].astype(xc.dtype))       # (B,S,di)
    A = -jnp.exp(p["A_log"])                                    # (di,ds) fp32

    dt32 = dt.astype(jnp.float32)
    x32 = xc.astype(jnp.float32)
    dtx = dt32 * x32                                            # (B,S,di)
    Bm = Bmat.astype(jnp.float32)
    Cm = Cmat.astype(jnp.float32)
    # time-major streams for the scan, in bf16 (state math stays f32;
    # halves the stream + residual HBM traffic)
    sd = jnp.bfloat16
    xs = (dt32.astype(sd).transpose(1, 0, 2),
          dtx.astype(sd).transpose(1, 0, 2),
          Bm.astype(sd).transpose(1, 0, 2),
          Cm.astype(sd).transpose(1, 0, 2))

    def step(h, s):
        dt_t, dtx_t, b_t, c_t = jax.tree.map(
            lambda a: a.astype(jnp.float32), s)
        dA_t = jnp.exp(dt_t[..., None] * A)                     # (B,di,ds)
        h = dA_t * h + dtx_t[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    h_init = jnp.zeros((B, di, ds), jnp.float32) if h0 is None else h0
    # remat the body: the scan's vjp residuals shrink from several stacked
    # (S,B,di,ds) tensors (decay, injection, ...) to just the state
    # trajectory — dA_t etc. are recomputed from the small streams in bwd
    h_last, ys = jax.lax.scan(jax.checkpoint(step), h_init, xs)
    y = ys.transpose(1, 0, 2) + p["D"] * x32                    # (B,S,di)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(xc.dtype)
    return y, h_last


def mamba1_block(p, x, cfg):
    """x: (B,S,d) -> (B,S,d)."""
    di = cfg.ssm.expand * cfg.d_model
    xz = x @ p["in_proj"].astype(x.dtype)
    xpart, z = jnp.split(xz, [di], axis=-1)
    xc = jax.nn.silu(causal_conv1d(xpart, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype)))
    xc = checkpoint_name(xc, "ssm_conv")
    y, _ = _mamba1_inner(p, xc, z, cfg)
    return y @ p["out_proj"].astype(x.dtype)


def init_mamba1_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    d, di, _, ds, K = mamba1_dims(cfg)
    return {"conv": jnp.zeros((batch, K - 1, di), dtype),
            "h": jnp.zeros((batch, di, ds), jnp.float32)}


def mamba1_decode_step(p, x, cache, cfg):
    """x: (B,1,d) -> (out (B,1,d), new_cache). O(1) in sequence length."""
    di = cfg.ssm.expand * cfg.d_model
    xz = x[:, 0] @ p["in_proj"].astype(x.dtype)
    xpart, z = jnp.split(xz, [di], axis=-1)
    conv_state, xc = conv_step(cache["conv"], xpart,
                               p["conv_w"].astype(x.dtype),
                               p["conv_b"].astype(x.dtype))
    xc = jax.nn.silu(xc).astype(x.dtype)   # cache dtype must not leak
    y, h = _mamba1_inner(p, xc[:, None], z[:, None], cfg, h0=cache["h"])
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"conv": conv_state, "h": h}


# ----------------------------------------------------------------------------
# Mamba-2 (SSD, chunked)
# ----------------------------------------------------------------------------

def mamba2_dims(cfg):
    d = cfg.d_model
    di = cfg.ssm.expand * d
    H = di // cfg.ssm.headdim
    return d, di, H, cfg.ssm.headdim, cfg.ssm.d_state, cfg.ssm.d_conv


def init_mamba2(rng, cfg) -> dict:
    d, di, H, P, N, K = mamba2_dims(cfg)
    conv_dim = di + 2 * N
    ks = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d)
    dt_init = jnp.exp(jax.random.uniform(ks[2], (H,)) *
                      (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di + 2 * N + H), jnp.float32) * s,
        "conv_w": jax.random.normal(ks[1], (conv_dim, K), jnp.float32) * 0.5,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt_init)),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[3], (di, d), jnp.float32) / math.sqrt(di),
    }


def _ssd_chunked(x, dt, Bm, Cm, A, chunk: int, h0=None):
    """SSD scan. x: (B,S,H,P); dt: (B,S,H); Bm/Cm: (B,S,N); A: (H,) negative.

    Returns (y (B,S,H,P), final_state (B,H,P,N)). Intra-chunk via dense
    matmuls; inter-chunk via lax.scan over S/chunk steps.
    """
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    S_orig = S
    if S % L:  # pad with dt=0 steps: decay 1 + zero input => exact
        pad = L - S % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    C = S // L

    xb = x.reshape(Bsz, C, L, H, Pd).astype(jnp.float32)
    dtb = dt.reshape(Bsz, C, L, H).astype(jnp.float32)
    Bb = Bm.reshape(Bsz, C, L, N).astype(jnp.float32)
    Cb = Cm.reshape(Bsz, C, L, N).astype(jnp.float32)

    la = jnp.cumsum(dtb * A, axis=2)                   # (B,C,L,H) log decay
    # intra-chunk: seg[i,j] = la_i - la_j (i >= j), else -inf
    seg = la[:, :, :, None] - la[:, :, None, :]        # (B,C,L,L,H)
    causal = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cb, Bb)         # (B,C,L,L)
    dtx = dtb[..., None] * xb                          # (B,C,L,H,P)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, decay, dtx)

    # chunk-final states: S_c = sum_j exp(la_last - la_j) dtx_j B_j^T
    last = la[:, :, -1:, :]                            # (B,C,1,H)
    w = jnp.exp(last - la)                             # (B,C,L,H)
    states = jnp.einsum("bcjh,bcjhp,bcjn->bchpn", w, dtx, Bb)

    chunk_decay = jnp.exp(la[:, :, -1, :])             # (B,C,H) total decay

    # carried chunk counter, not a jnp.arange xs: iota scan operands trip
    # the SPMD partitioner inside partial-auto shard_map (see
    # layers._blockwise_attention); a carried counter is bit-identical.
    def step(carry, _):
        h, c = carry
        y_off_c = jnp.einsum("bin,bih,bhpn->bihp",
                             Cb[:, c], jnp.exp(la[:, c]), h)
        h = chunk_decay[:, c][..., None, None] * h + states[:, c]
        return (h, c + 1), y_off_c

    h_init = (jnp.zeros((Bsz, H, Pd, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    (h_last, _), y_off = jax.lax.scan(step, (h_init, jnp.int32(0)),
                                      None, length=C)
    y_off = y_off.transpose(1, 0, 2, 3, 4)             # (B,C,L,H,P)
    y = (y_diag + y_off).reshape(Bsz, S, H, Pd)
    return y[:, :S_orig], h_last


def _gated_rmsnorm(y, z, scale, eps=1e-6):
    y = y * jax.nn.silu(z.astype(y.dtype))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + eps) * scale


def mamba2_block(p, x, cfg):
    """x: (B,S,d) -> (B,S,d) via chunked SSD."""
    d, di, H, Pd, N, K = mamba2_dims(cfg)
    B, S, _ = x.shape
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    xBC = jax.nn.silu(causal_conv1d(xBC, p["conv_w"].astype(x.dtype),
                                    p["conv_b"].astype(x.dtype)))
    xBC = checkpoint_name(xBC, "ssm_conv")
    xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    xh = xs.reshape(B, S, H, Pd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = _ssd_chunked(xh, dt, Bm, Cm, A, cfg.ssm.chunk)
    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di)
    y = _gated_rmsnorm(y, z, p["norm_scale"]).astype(x.dtype)
    return y @ p["out_proj"].astype(x.dtype)


def init_mamba2_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    d, di, H, Pd, N, K = mamba2_dims(cfg)
    return {"conv": jnp.zeros((batch, K - 1, di + 2 * N), dtype),
            "h": jnp.zeros((batch, H, Pd, N), jnp.float32)}


def mamba2_decode_step(p, x, cache, cfg):
    """x: (B,1,d) single-token step with O(1) state."""
    d, di, H, Pd, N, K = mamba2_dims(cfg)
    B = x.shape[0]
    zxbcdt = x[:, 0] @ p["in_proj"].astype(x.dtype)
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    conv_state, xBC = conv_step(cache["conv"], xBC,
                                p["conv_w"].astype(x.dtype),
                                p["conv_b"].astype(x.dtype))
    xBC = jax.nn.silu(xBC).astype(x.dtype)  # cache dtype must not leak
    xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    xh = xs.reshape(B, H, Pd).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                           # (B,H)
    h = (a[..., None, None] * cache["h"]
         + dt[..., None, None] * xh[..., None] * Bm[:, None, None, :].astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + p["D"][:, None] * xh
    y = y.reshape(B, di)
    y = _gated_rmsnorm(y, z, p["norm_scale"]).astype(x.dtype)
    out = (y @ p["out_proj"].astype(x.dtype))[:, None]
    return out, {"conv": conv_state, "h": h}
