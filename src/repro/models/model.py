"""Unified model builder for all supported architecture families.

``init_params`` / ``forward`` / ``loss_fn`` / ``init_cache`` / ``decode_step``
dispatch on ``cfg.arch_type`` in {dense, moe, vlm, ssm, hybrid, audio}.

Layer stacks are *scanned* (stacked params with a leading layer dim +
``lax.scan``) so that HLO size and compile time stay flat in depth — the
standard large-model JAX pattern. The zamba2-style hybrid scans over
"macro-groups" of ``shared_attn_every`` mamba layers followed by one
application of the shared-weight attention+MLP block.

Selective activation checkpointing (paper §1 SAC) wraps the selected
sub-modules (norm / attn / moe / mlp / block) in ``jax.checkpoint``: only the
module inputs are saved, its internals recomputed in backward — exactly the
paper's semantics.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import moe as moe_lib
from . import layers as L
from . import ssm as S

VOCAB_ALIGN = 256


def padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab_size // VOCAB_ALIGN) * VOCAB_ALIGN


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------

def _init_dense_layer(rng, cfg):
    k1, k2 = jax.random.split(rng)
    return {"ln1": L.init_norm(cfg.norm, cfg.d_model),
            "attn": L.init_attention(k1, cfg),
            "ln2": L.init_norm(cfg.norm, cfg.d_model),
            "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_activation)}


def _init_moe_layer(rng, cfg):
    k1, k2 = jax.random.split(rng)
    return {"ln1": L.init_norm(cfg.norm, cfg.d_model),
            "attn": L.init_attention(k1, cfg),
            "ln2": L.init_norm(cfg.norm, cfg.d_model),
            "moe": moe_lib.init_moe_block(k2, cfg)}


def _init_ssm_layer(rng, cfg):
    mixer = (S.init_mamba1 if cfg.ssm.variant == "mamba1" else S.init_mamba2)
    return {"ln": L.init_norm(cfg.norm, cfg.d_model), "mixer": mixer(rng, cfg)}


def _init_xattn_layer(rng, cfg):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {"ln1": L.init_norm(cfg.norm, cfg.d_model),
            "attn": L.init_attention(k1, cfg),
            "lnx": L.init_norm(cfg.norm, cfg.d_model),
            "xattn": L.init_attention(k2, cfg),
            "ln2": L.init_norm(cfg.norm, cfg.d_model),
            "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_activation)}


def _stack(init_fn, rng, n, cfg):
    return jax.vmap(lambda r: init_fn(r, cfg))(jax.random.split(rng, n))


def init_params(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 8)
    vp = padded_vocab(cfg)
    p = {"embed": L.init_embedding(ks[0], vp, cfg.d_model),
         "final_norm": L.init_norm(cfg.norm, cfg.d_model)}
    if not cfg.tie_embeddings:
        p["head"] = L.init_embedding(ks[1], vp, cfg.d_model)

    at = cfg.arch_type
    if at in ("dense", "vlm"):
        p["layers"] = _stack(_init_dense_layer, ks[2], cfg.num_layers, cfg)
    elif at == "moe":
        p["layers"] = _stack(_init_moe_layer, ks[2], cfg.num_layers, cfg)
    elif at == "ssm":
        p["layers"] = _stack(_init_ssm_layer, ks[2], cfg.num_layers, cfg)
    elif at == "hybrid":
        every = cfg.shared_attn_every
        n_group = cfg.num_layers // every
        rem = cfg.num_layers - n_group * every
        p["groups"] = jax.vmap(lambda r: _stack(_init_ssm_layer, r, every, cfg))(
            jax.random.split(ks[2], n_group))
        if rem:
            p["rem"] = _stack(_init_ssm_layer, ks[3], rem, cfg)
        k1, k2 = jax.random.split(ks[4])
        p["shared"] = {"ln1": L.init_norm(cfg.norm, cfg.d_model),
                       "attn": L.init_attention(k1, cfg),
                       "ln2": L.init_norm(cfg.norm, cfg.d_model),
                       "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff,
                                         cfg.mlp_activation)}
    elif at == "audio":
        p["enc_layers"] = _stack(_init_dense_layer, ks[2],
                                 cfg.num_encoder_layers, cfg)
        p["enc_norm"] = L.init_norm(cfg.norm, cfg.d_model)
        p["dec_layers"] = _stack(_init_xattn_layer, ks[3], cfg.num_layers, cfg)
    else:
        raise ValueError(f"unknown arch_type {at}")
    if at == "vlm":
        p["img_proj"] = {"w": jax.random.normal(
            ks[5], (cfg.d_model, cfg.d_model), jnp.float32) / math.sqrt(cfg.d_model)}
    return p


# ----------------------------------------------------------------------------
# SAC wrappers
# ----------------------------------------------------------------------------

def _sac(fn, name: str, policy: str):
    """Wrap ``fn`` in jax.checkpoint when its module is selected by the SAC
    policy (comma-separated set, e.g. 'attn,moe')."""
    selected = set(policy.split(",")) if policy else set()
    if name in selected:
        return jax.checkpoint(fn)
    return fn


def block_remat(fn, sac: str):
    """Whole-block remat variants:
    'block'    — save only block inputs (paper SAC; collectives replayed);
    'block_sc' — like 'block' but *save collective outputs* (attn_proj_out,
                 moe_out), so backward recompute does not re-run the TP/EP
                 all-reduces (beyond-paper §Perf lever)."""
    modes = set(sac.split(",")) if sac else set()
    if "block_sc" in modes:
        policy = jax.checkpoint_policies.save_only_these_names(
            "attn_proj_out", "moe_out")
        return jax.checkpoint(fn, policy=policy)
    if "block" in modes:
        return jax.checkpoint(fn)
    return fn


# ----------------------------------------------------------------------------
# blocks
# ----------------------------------------------------------------------------

def _dense_block(lp, h, cfg, rules, sac: str, causal=True):
    cons = rules.constrain if rules else (lambda x, n: x)
    attn = _sac(lambda q, x: L.attention(q, x, cfg, constrain=cons,
                                         causal=causal), "attn", sac)
    mlp = _sac(lambda q, x: L.apply_mlp(q, x, cfg.mlp_activation, cons),
               "mlp", sac)
    h = h + attn(lp["attn"], L.apply_norm(lp["ln1"], h, cfg.norm))
    h = h + mlp(lp["mlp"], L.apply_norm(lp["ln2"], h, cfg.norm))
    return cons(h, "act_btd")


def _moe_block(lp, h, cfg, rules, sac: str, mesh, placement=None):
    cons = rules.constrain if rules else (lambda x, n: x)
    ep_axis = rules.ep_axis if rules else None
    tp_axis = rules.tp_axis if rules else None
    # token/batch axes for the MoE dispatch exclude the EP axis itself
    # (tokens reshard over it inside the block)
    batch_axes = tuple(a for a in (rules.batch_axes if rules else ())
                       if a != ep_axis)
    # EP shard_map path only when the rules assign an EP axis; under
    # 'etp'/'tp'-only placements the capacity path auto-shards instead.
    mesh_eff = mesh if ep_axis else None
    attn = _sac(lambda q, x: L.attention(q, x, cfg, constrain=cons),
                "attn", sac)
    c_align = 1
    if rules is not None and rules.mesh is not None and rules.batch_axes:
        c_align = rules._axis_size(tuple(rules.batch_axes))
    tp_mesh = mesh if tp_axis else None
    moe = _sac(lambda q, x: moe_lib.sparse_moe_block(
        q, x, cfg, mesh=mesh_eff, ep_axis=ep_axis or "model",
        batch_axes=batch_axes, constrain=cons,
        c_align=c_align, tp_mesh=tp_mesh, tp_axis=tp_axis,
        placement=placement), "moe", sac)
    h = h + attn(lp["attn"], L.apply_norm(lp["ln1"], h, cfg.norm))
    mo, aux, z, stats = moe(lp["moe"], L.apply_norm(lp["ln2"], h, cfg.norm))
    h = h + mo
    return cons(h, "act_btd"), aux, z, stats


def _ssm_block(lp, h, cfg, rules, sac: str):
    cons = rules.constrain if rules else (lambda x, n: x)
    mixer = S.mamba1_block if cfg.ssm.variant == "mamba1" else S.mamba2_block
    fn = _sac(lambda q, x: mixer(q, x, cfg), "ssm", sac)
    h = h + fn(lp["mixer"], L.apply_norm(lp["ln"], h, cfg.norm))
    return cons(h, "act_btd")


def _xattn_block(lp, h, mem, cfg, rules, sac: str):
    cons = rules.constrain if rules else (lambda x, n: x)
    attn = _sac(lambda q, x: L.attention(q, x, cfg, constrain=cons),
                "attn", sac)
    xatt = _sac(lambda q, x, m: L.attention(q, x, cfg, constrain=cons,
                                            memory=m), "attn", sac)
    mlp = _sac(lambda q, x: L.apply_mlp(q, x, cfg.mlp_activation, cons),
               "mlp", sac)
    h = h + attn(lp["attn"], L.apply_norm(lp["ln1"], h, cfg.norm))
    h = h + xatt(lp["xattn"], L.apply_norm(lp["lnx"], h, cfg.norm), mem)
    h = h + mlp(lp["mlp"], L.apply_norm(lp["ln2"], h, cfg.norm))
    return cons(h, "act_btd")


def _scan_layers(stacked, h, body, sac: str):
    """lax.scan over a stacked layer pytree. body(lp, h) -> h."""
    fn = block_remat(body, sac)

    def step(carry, lp):
        return fn(lp, carry), None

    h, _ = jax.lax.scan(step, h, stacked)
    return h


def _scan_layers_aux(stacked, h, body, sac: str, num_experts: int,
                     placement=None):
    """Like _scan_layers but body(lp, h, pl) returns (h, aux, z, MoeStats)
    — aux losses and routing telemetry accumulated (summed) across layers.
    ``placement``: optional (L, E) int32 inverse placement rows scanned
    alongside the stacked params, so each layer dispatches against its own
    row (None — an empty pytree — scans through untouched)."""
    fn = block_remat(body, sac)

    def step(carry, xs):
        lp, pl = xs
        h, aux, z, st = carry
        h, a, zz, s = fn(lp, h, pl)
        return (h, aux + a, z + zz, st + s), None

    (h, aux, z, st), _ = jax.lax.scan(
        step, (h, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
               moe_lib.MoeStats.zero(num_experts)),
        (stacked, placement))
    return h, aux, z, st


# ----------------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------------

def forward(params, batch: dict, cfg: ModelConfig, *,
            rules=None, mesh=None, sac: str = "block",
            compute_dtype=jnp.bfloat16, placement=None):
    """Returns (logits (B, S_out, V_pad), aux_losses dict).

    ``placement``: optional (L, E) int32 inverse expert-placement rows
    (global expert id -> stored position per layer; parallel/placement.py)
    when the stacked MoE expert weights live in a re-placed order."""
    cons = rules.constrain if rules else (lambda x, n: x)
    aux = {"moe_aux": jnp.zeros((), jnp.float32),
           "moe_z": jnp.zeros((), jnp.float32)}
    at = cfg.arch_type

    if at == "audio":
        enc_h = batch["frame_embeds"].astype(compute_dtype)
        enc_h = cons(enc_h, "act_btd")
        enc_h = _scan_layers(
            params["enc_layers"], enc_h,
            lambda lp, h: _dense_block(lp, h, cfg, rules, sac, causal=False),
            sac)
        mem = L.apply_norm(params["enc_norm"], enc_h, cfg.norm)
        h = L.embed(params["embed"], batch["tokens"], compute_dtype)
        h = cons(h, "act_btd")
        h = _scan_layers(
            params["dec_layers"], h,
            lambda lp, hh: _xattn_block(lp, hh, mem, cfg, rules, sac), sac)
    else:
        h = L.embed(params["embed"], batch["tokens"], compute_dtype)
        if at == "vlm":
            img = batch["image_embeds"].astype(compute_dtype)
            img = img @ params["img_proj"]["w"].astype(compute_dtype)
            h = jnp.concatenate([img, h], axis=1)
        h = cons(h, "act_btd")
        if at in ("dense", "vlm"):
            h = _scan_layers(params["layers"], h,
                             lambda lp, hh: _dense_block(lp, hh, cfg, rules, sac),
                             sac)
        elif at == "moe":
            h, a, z, st = _scan_layers_aux(
                params["layers"], h,
                lambda lp, hh, pl: _moe_block(lp, hh, cfg, rules, sac, mesh,
                                              placement=pl),
                sac, cfg.moe.num_experts, placement=placement)
            aux["moe_aux"], aux["moe_z"] = a, z
            aux["moe_stats"] = st
        elif at == "ssm":
            h = _scan_layers(params["layers"], h,
                             lambda lp, hh: _ssm_block(lp, hh, cfg, rules, sac),
                             sac)
        elif at == "hybrid":
            def group_body(gp, hh):
                hh = _scan_layers(
                    gp, hh, lambda lp, x: _ssm_block(lp, x, cfg, rules, sac),
                    sac)
                return _dense_block(params["shared"], hh, cfg, rules, sac)

            def gstep(carry, gp):
                return group_body(gp, carry), None

            h, _ = jax.lax.scan(gstep, h, params["groups"])
            if "rem" in params:
                h = _scan_layers(
                    params["rem"], h,
                    lambda lp, x: _ssm_block(lp, x, cfg, rules, sac), sac)
        else:
            raise ValueError(at)

    h = L.apply_norm(params["final_norm"], h, cfg.norm)
    head = params.get("head", params["embed"])
    logits = L.unembed(head, h)
    logits = cons(logits, "logits")
    return logits, aux


# ----------------------------------------------------------------------------
# loss
# ----------------------------------------------------------------------------

def masked_ce(logits, labels, cfg: ModelConfig):
    """Masked next-token CE over padded-vocab logits. Returns (ce, ntok)."""
    vp = padded_vocab(cfg)
    logits = logits.astype(jnp.float32)
    if vp != cfg.vocab_size:     # mask padded vocab columns out of the lse
        pad_mask = jnp.arange(vp) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e9, logits)
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(mask, lse - ll, 0.0)
    ntok = jnp.maximum(mask.sum(), 1)
    return nll.sum() / ntok, ntok


def loss_fn(params, batch, cfg: ModelConfig, *, rules=None, mesh=None,
            sac: str = "block", compute_dtype=jnp.bfloat16, placement=None):
    """Next-token cross entropy (+ MoE aux losses). labels = -100 masked."""
    logits, aux = forward(params, batch, cfg, rules=rules, mesh=mesh,
                          sac=sac, compute_dtype=compute_dtype,
                          placement=placement)
    labels = batch["labels"]
    if cfg.arch_type == "vlm":   # prefix image positions produce no loss
        logits = logits[:, cfg.num_prefix_embeds:]
    ce, ntok = masked_ce(logits, labels, cfg)
    total = ce
    if cfg.is_moe:
        total = total + cfg.moe.router_aux_coef * aux["moe_aux"] / cfg.num_layers
        total = total + cfg.moe.router_z_coef * aux["moe_z"] / cfg.num_layers
    metrics = {"ce": ce, "moe_aux": aux["moe_aux"] / max(cfg.num_layers, 1),
               "moe_z": aux["moe_z"] / max(cfg.num_layers, 1), "ntok": ntok}
    if "moe_stats" in aux:
        st = aux["moe_stats"]
        counts = st.counts / max(cfg.num_layers, 1)   # per-layer mean -> T*K
        metrics["moe_counts"] = counts
        metrics["moe_load"] = counts / jnp.maximum(counts.sum(), 1.0)
        metrics["moe_drops"] = st.drops               # summed over layers
    return total, metrics


# ----------------------------------------------------------------------------
# pipeline-stage pieces (the jitted PP train path; parallel/pipeline.py)
# ----------------------------------------------------------------------------

PP_ARCH_TYPES = ("dense", "moe", "ssm")   # uniform scanned 'layers' stacks


def embed_tokens(params, tokens, cfg: ModelConfig, *,
                 compute_dtype=jnp.bfloat16):
    """Stage-0 input: token embedding, exactly as ``forward`` computes it."""
    return L.embed(params["embed"], tokens, compute_dtype)


def pipeline_stage_forward(stage_lp, h, cfg: ModelConfig, *, sac: str = ""):
    """Apply one pipeline stage's (L/pp, ...)-stacked layer slice to ``h``.

    The same block functions and scan the full ``forward`` uses, so running
    the pp stage slices back-to-back reproduces the sequential model
    bit-for-bit. Blocks run without sharding-rule constraints (the PP
    executor pins placement at stage granularity instead); MoE stages
    therefore always take the auto-shardable dense path (``c_align=1``,
    capacity or dropless per ``cfg.moe.dispatch``), never the EP shard_map
    path. Returns (h, moe_aux, moe_z, MoeStats)."""
    at = cfg.arch_type
    if at not in PP_ARCH_TYPES:
        raise ValueError(
            f"pipeline parallelism supports arch_type in {PP_ARCH_TYPES}, "
            f"not {at!r} (non-uniform layer stacks)")
    if at == "moe":
        return _scan_layers_aux(
            stage_lp, h,
            lambda lp, hh, pl: _moe_block(lp, hh, cfg, None, sac, None,
                                          placement=pl),
            sac, cfg.moe.num_experts)
    if at == "dense":
        h = _scan_layers(stage_lp, h,
                         lambda lp, hh: _dense_block(lp, hh, cfg, None, sac),
                         sac)
    else:
        h = _scan_layers(stage_lp, h,
                         lambda lp, hh: _ssm_block(lp, hh, cfg, None, sac),
                         sac)
    return (h, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
            moe_lib.MoeStats.zero(0))


def lm_head_ce(params, h, labels, cfg: ModelConfig):
    """Last-stage tail: final norm + unembed + masked CE — the same ops
    ``forward`` + ``loss_fn`` apply after the layer stack. Returns ce."""
    h = L.apply_norm(params["final_norm"], h, cfg.norm)
    head = params.get("head", params["embed"])
    ce, _ = masked_ce(L.unembed(head, h), labels, cfg)
    return ce


# ----------------------------------------------------------------------------
# decode (serve_step)
# ----------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Per-layer stacked caches (leading dim = layer)."""
    at = cfg.arch_type

    def stack(make, n):
        one = make()
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)

    if at in ("dense", "vlm", "moe"):
        return {"kv": stack(lambda: L.init_kv_cache(cfg, batch, max_len, dtype),
                            cfg.num_layers)}
    if at == "ssm":
        mk = (S.init_mamba1_cache if cfg.ssm.variant == "mamba1"
              else S.init_mamba2_cache)
        return {"ssm": stack(lambda: mk(cfg, batch), cfg.num_layers)}
    if at == "hybrid":
        every = cfg.shared_attn_every
        n_group = cfg.num_layers // every
        rem = cfg.num_layers - n_group * every
        c = {"groups": stack(lambda: S.init_mamba2_cache(cfg, batch),
                             n_group * every),
             "shared_kv": stack(lambda: L.init_kv_cache(cfg, batch, max_len,
                                                        dtype), n_group)}
        if rem:
            c["rem"] = stack(lambda: S.init_mamba2_cache(cfg, batch), rem)
        return c
    if at == "audio":
        return {"kv": stack(lambda: L.init_kv_cache(cfg, batch, max_len, dtype),
                            cfg.num_layers),
                "memory": jnp.zeros((batch, max_len, cfg.d_model), dtype)}
    raise ValueError(at)


def decode_step(params, tokens, cache: dict, index, cfg: ModelConfig, *,
                rules=None, compute_dtype=jnp.bfloat16):
    """One decode step. tokens: (B, 1) int32; index: scalar position, or a
    (B,) int32 vector of per-row positions (continuous batching — each cache
    row advances independently; see repro/serve/engine.py).
    Returns (logits (B, 1, V_pad), new_cache)."""
    cons = rules.constrain if rules else (lambda x, n: x)
    at = cfg.arch_type
    h = L.embed(params["embed"], tokens, compute_dtype)
    new_cache = dict(cache)

    def attn_step(lp, hh, kv):
        a, kv2 = L.decode_attention(lp["attn"], L.apply_norm(lp["ln1"], hh,
                                                             cfg.norm),
                                    kv, index, cfg, constrain=cons)
        return hh + a, kv2

    if at in ("dense", "vlm", "moe"):
        def step(carry, xs):
            hh = carry
            lp, kv = xs
            hh, kv2 = attn_step(lp, hh, kv)
            x2 = L.apply_norm(lp["ln2"], hh, cfg.norm)
            if at == "moe":
                mo, _, _, _ = moe_lib.sparse_moe_block(lp["moe"], x2, cfg,
                                                       mesh=None)
                hh = hh + mo
            else:
                hh = hh + L.apply_mlp(lp["mlp"], x2, cfg.mlp_activation, cons)
            return hh, kv2

        h, kv_new = jax.lax.scan(step, h, (params["layers"], cache["kv"]))
        new_cache["kv"] = kv_new
    elif at == "ssm":
        mixer_step = (S.mamba1_decode_step if cfg.ssm.variant == "mamba1"
                      else S.mamba2_decode_step)

        def step(carry, xs):
            hh = carry
            lp, c = xs
            y, c2 = mixer_step(lp["mixer"], L.apply_norm(lp["ln"], hh, cfg.norm),
                               c, cfg)
            return hh + y, c2

        h, ssm_new = jax.lax.scan(step, h, (params["layers"], cache["ssm"]))
        new_cache["ssm"] = ssm_new
    elif at == "hybrid":
        every = cfg.shared_attn_every
        n_group = params["groups"]["ln"]["scale"].shape[0]

        def mamba_step(carry, xs):
            hh = carry
            lp, c = xs
            y, c2 = S.mamba2_decode_step(lp["mixer"],
                                         L.apply_norm(lp["ln"], hh, cfg.norm),
                                         c, cfg)
            return hh + y, c2

        def group_step(carry, xs):
            hh = carry
            gp, gc, skv = xs
            hh, gc2 = jax.lax.scan(mamba_step, hh, (gp, gc))
            a, skv2 = L.decode_attention(
                params["shared"]["attn"],
                L.apply_norm(params["shared"]["ln1"], hh, cfg.norm),
                skv, index, cfg, constrain=cons)
            hh = hh + a
            hh = hh + L.apply_mlp(params["shared"]["mlp"],
                                  L.apply_norm(params["shared"]["ln2"], hh,
                                               cfg.norm),
                                  cfg.mlp_activation, cons)
            return hh, (gc2, skv2)

        gc = jax.tree.map(
            lambda a: a.reshape((n_group, every) + a.shape[1:]),
            cache["groups"])
        h, (gc2, skv2) = jax.lax.scan(group_step, h,
                                      (params["groups"], gc,
                                       cache["shared_kv"]))
        new_cache["groups"] = jax.tree.map(
            lambda a: a.reshape((-1,) + a.shape[2:]), gc2)
        new_cache["shared_kv"] = skv2
        if "rem" in params:
            h, rem2 = jax.lax.scan(mamba_step, h,
                                   (params["rem"], cache["rem"]))
            new_cache["rem"] = rem2
    elif at == "audio":
        mem = cache["memory"].astype(compute_dtype)

        def step(carry, xs):
            hh = carry
            lp, kv = xs
            hh, kv2 = attn_step(lp, hh, kv)
            x = L.apply_norm(lp["lnx"], hh, cfg.norm)
            hh = hh + L.attention(lp["xattn"], x, cfg, constrain=cons,
                                  memory=mem)
            hh = hh + L.apply_mlp(lp["mlp"],
                                  L.apply_norm(lp["ln2"], hh, cfg.norm),
                                  cfg.mlp_activation, cons)
            return hh, kv2

        h, kv_new = jax.lax.scan(step, h, (params["dec_layers"], cache["kv"]))
        new_cache["kv"] = kv_new
    else:
        raise ValueError(at)

    h = L.apply_norm(params["final_norm"], h, cfg.norm)
    head = params.get("head", params["embed"])
    logits = L.unembed(head, h)
    return cons(logits, "logits"), new_cache


# ----------------------------------------------------------------------------
# prefill into cache slots (serve admission path)
# ----------------------------------------------------------------------------

def prefill_with_cache(params, tokens, cache: dict, slots, lengths,
                       cfg: ModelConfig, *, rules=None, mesh=None,
                       compute_dtype=jnp.bfloat16):
    """Prefill right-padded prompts directly into KV-cache rows.

    tokens: (B', P) int32, right-padded; slots: (B',) int32 cache rows to
    fill; lengths: (B',) valid prompt lengths (1 <= length <= P). Causal
    masking keeps padded columns from contaminating real positions, and the
    K/V of padded (or window-expired) positions are dropped by the scatter.
    Ring (sliding-window) caches keep only the last ``window`` positions,
    laid out at ``position % window`` — exactly the layout ``decode_step``
    expects to find.

    Returns (last_logits (B', V_pad) — the logits at position length-1 of
    each row, i.e. the distribution of the first generated token — and the
    updated cache). Attention-KV archs only (dense, moe); recurrent-state
    archs prefill by stepping ``decode_step`` over the prompt instead.
    """
    at = cfg.arch_type
    if at not in ("dense", "moe"):
        raise NotImplementedError(
            f"prefill_with_cache supports attention-KV archs, not {at!r}")
    cons = rules.constrain if rules else (lambda x, n: x)
    h = L.embed(params["embed"], tokens, compute_dtype)
    h = cons(h, "act_btd")

    def step(carry, lp):
        hh = carry
        a, kv = L.attention(lp["attn"], L.apply_norm(lp["ln1"], hh, cfg.norm),
                            cfg, constrain=cons, return_kv=True)
        hh = hh + a
        x2 = L.apply_norm(lp["ln2"], hh, cfg.norm)
        if at == "moe":
            # single-host capacity path, matching decode_step; ``mesh`` is
            # accepted for signature parity but EP dispatch is not wired
            # into serving yet (multi-host serve is a ROADMAP item)
            mo, _, _, _ = moe_lib.sparse_moe_block(lp["moe"], x2, cfg,
                                                   mesh=None)
            hh = hh + mo
        else:
            hh = hh + L.apply_mlp(lp["mlp"], x2, cfg.mlp_activation, cons)
        return hh, kv

    h, (ks, vs) = jax.lax.scan(step, h, params["layers"])  # (L, B', P, ...)

    ck, cv = cache["kv"]["k"], cache["kv"]["v"]            # (L, B, W, n, hd)
    W = ck.shape[2]
    P = tokens.shape[1]
    lengths = jnp.asarray(lengths, jnp.int32)
    slots = jnp.asarray(slots, jnp.int32)
    pos = jnp.arange(P)[None, :]                           # (1, P)
    keep = (pos < lengths[:, None]) & (pos >= lengths[:, None] - W)
    if cfg.sliding_window > 0:
        dest = jnp.where(keep, pos % W, W)                 # W => dropped
    else:
        dest = jnp.where(keep & (pos < W), pos, W)
    rows = jnp.broadcast_to(slots[:, None], dest.shape)
    new_cache = dict(cache)
    new_cache["kv"] = {
        "k": ck.at[:, rows, dest].set(ks.astype(ck.dtype), mode="drop"),
        "v": cv.at[:, rows, dest].set(vs.astype(cv.dtype), mode="drop"),
    }

    h = L.apply_norm(params["final_norm"], h, cfg.norm)
    head = params.get("head", params["embed"])
    logits = cons(L.unembed(head, h), "logits")            # (B', P, V_pad)
    last = jnp.take_along_axis(logits, (lengths - 1)[:, None, None], axis=1)
    return last[:, 0], new_cache
