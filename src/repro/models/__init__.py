from .model import (init_params, forward, loss_fn, init_cache, decode_step,
                    prefill_with_cache, padded_vocab, masked_ce,
                    embed_tokens, pipeline_stage_forward, lm_head_ce,
                    PP_ARCH_TYPES)
