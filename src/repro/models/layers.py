"""Core transformer layers: norms, RoPE, GQA attention (full / sliding-window,
train / prefill / decode with KV cache), MLPs.

Functional style: params are plain dict pytrees; every layer is
``init_*(rng, ...) -> params`` + a pure apply function. Activation sharding
constraints are threaded via an optional ``constrain`` callable (see
repro.parallel.sharding).
"""
from __future__ import annotations

import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.kernels.ref import slot_decode_attention_ref
from repro.parallel.plan import current_kernel_plan

Constrain = Callable[[jax.Array, str], jax.Array]  # (x, logical_spec_name)

# Probe mode (launch/costmodel.py): forces single-block attention so the
# blockwise scans have trip count 1 and XLA cost analysis (which counts
# while bodies once) is exact. None = use the q_block/kv_block arguments.
ATTN_BLOCK_OVERRIDE = None


# The attention implementation — 'blockwise' (pure-JAX online-softmax; has a
# backward, used for training) | 'pallas' (repro/kernels/flash_attention.py,
# forward-only — serving/prefill on TPU; interpret mode on CPU) — is the
# active KernelPlan's ``attn_impl`` (plan-scoped; no module-global state).
# Tombstone: the PR 4 module-global alias (and its __getattr__ shim) is
# deleted; lint rule SL004 forbids the symbol repo-wide. Scope a plan with
# use_kernel_plan to select an implementation.
def _attn_impl() -> str:
    return current_kernel_plan().attn_impl


def no_constrain(x, _name):
    return x


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------

def init_norm(kind: str, d: int):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(params, x, kind: str = "rmsnorm", eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if kind == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
        out = x * params["scale"]
    else:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        out = (x - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return checkpoint_name(out.astype(dtype), "norm_out")


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Attention (GQA, full or sliding window)
# ----------------------------------------------------------------------------

def init_attention(rng, cfg) -> dict:
    d, nh, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "wq": jax.random.normal(k1, (d, nh * hd), jnp.float32) * s,
        "wk": jax.random.normal(k2, (d, nkv * hd), jnp.float32) * s,
        "wv": jax.random.normal(k3, (d, nkv * hd), jnp.float32) * s,
        "wo": jax.random.normal(k4, (nh * hd, d), jnp.float32) * s,
    }


def _blockwise_attention(q, k, v, *, causal: bool, window: int,
                         q_offset: int | jax.Array = 0,
                         q_block: int = 512, kv_block: int = 512):
    """Flash-style double-blocked attention in pure JAX (online softmax).

    q: (B, Sq, nh, hd); k/v: (B, Skv, nkv, hd). Memory O(B*nh*q_block*kv_block).
    ``q_offset`` is the absolute position of q[0] relative to k[0] (for
    prefill-with-cache / cross-chunk cases). ``window``>0 => sliding window
    (each query attends to keys in (pos-window, pos]).
    """
    B, Sq, nh, hd = q.shape
    Skv, nkv = k.shape[1], k.shape[2]
    groups = nh // nkv
    scale = 1.0 / math.sqrt(hd)

    if ATTN_BLOCK_OVERRIDE is not None:
        q_block = kv_block = ATTN_BLOCK_OVERRIDE
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    nq = -(-Sq // qb)
    nk = -(-Skv // kb)
    Sq_pad, Skv_pad = nq * qb, nk * kb
    q = jnp.pad(q, ((0, 0), (0, Sq_pad - Sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, Skv_pad - Skv), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, Skv_pad - Skv), (0, 0), (0, 0)))

    # (B, nkv, groups, nq, qb, hd)
    qr = q.reshape(B, nq, qb, nkv, groups, hd).transpose(0, 3, 4, 1, 2, 5)
    kr = k.reshape(B, nk, kb, nkv, hd).transpose(0, 3, 1, 2, 4)   # (B,nkv,nk,kb,hd)
    vr = v.reshape(B, nk, kb, nkv, hd).transpose(0, 3, 1, 2, 4)

    q_pos = q_offset + jnp.arange(Sq_pad).reshape(nq, qb)
    kv_pos = jnp.arange(Skv_pad).reshape(nk, kb)

    neg = jnp.float32(-1e30)

    # NOTE: both block scans walk a *carried* int32 counter instead of
    # scanning over a jnp.arange xs: an iota-valued scan operand trips the
    # SPMD partitioner inside partial-auto shard_map regions (the
    # per-stage pipeline executor) on jax 0.4.x — "Check failed:
    # sharding.IsManualSubgroup()". A carried counter is bit-identical.
    def q_step(qi, _):
        qt = qr[:, :, :, qi].astype(jnp.float32) * scale   # (B,nkv,g,qb,hd)
        qp = q_pos[qi]                                     # (qb,)

        def kv_step(carry, _):
            m, l, acc, ki = carry
            kt = kr[:, :, ki].astype(jnp.float32)          # (B,nkv,kb,hd)
            vt = vr[:, :, ki].astype(jnp.float32)
            s = jnp.einsum("bngqh,bnkh->bngqk", qt, kt)    # (B,nkv,g,qb,kb)
            kp = kv_pos[ki]
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window > 0:
                mask &= qp[:, None] - kp[None, :] < window
            mask &= (kp < Skv)[None, :]                    # kv padding
            s = jnp.where(mask[None, None, None], s, neg)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bngqk,bnkh->bngqh", p, vt)
            return (m_new, l_new, acc_new, ki + 1), None

        m0 = jnp.full((B, nkv, groups, qb), neg)
        l0 = jnp.zeros((B, nkv, groups, qb))
        a0 = jnp.zeros((B, nkv, groups, qb, hd))
        (m, l, acc, _), _ = jax.lax.scan(kv_step, (m0, l0, a0, jnp.int32(0)),
                                         None, length=nk)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return qi + 1, out

    _, o = jax.lax.scan(q_step, jnp.int32(0), None,
                        length=nq)                         # (nq,B,nkv,g,qb,hd)
    o = o.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq_pad, nh, hd)
    return o[:, :Sq].astype(q.dtype)


def attention(params, x, cfg, *, constrain: Constrain = no_constrain,
              memory: Optional[jax.Array] = None, causal: bool = True,
              positions: Optional[jax.Array] = None,
              return_kv: bool = False,
              q_block: int = 512, kv_block: int = 512):
    """Self- (or cross-, if ``memory`` given) attention for train/prefill.

    x: (B, S, d). Cross-attention is non-causal over ``memory``.
    ``return_kv`` additionally returns the (k, v) tensors for cache prefill.
    """
    B, S, d = x.shape
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    src = x if memory is None else memory
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, nh, hd)
    k = (src @ params["wk"].astype(x.dtype)).reshape(B, src.shape[1], nkv, hd)
    v = (src @ params["wv"].astype(x.dtype)).reshape(B, src.shape[1], nkv, hd)
    q = constrain(q, "act_heads")
    k = constrain(k, "act_kv_heads")
    v = constrain(v, "act_kv_heads")
    if memory is None:  # RoPE only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if _attn_impl() == "pallas":
        from repro.kernels.ops import flash_attention
        o = flash_attention(q, k, v, causal=(causal and memory is None),
                            window=cfg.sliding_window if memory is None else 0,
                            q_block=q_block, kv_block=kv_block).astype(x.dtype)
    else:
        o = _blockwise_attention(
            q, k, v, causal=(causal and memory is None),
            window=cfg.sliding_window if memory is None else 0,
            q_block=q_block, kv_block=kv_block)
    o = checkpoint_name(o, "attn_out")
    out = o.reshape(B, S, nh * hd) @ params["wo"].astype(x.dtype)
    out = constrain(out, "act_btd")
    # post-TP-allreduce activation: saving it under the 'block_sc' SAC policy
    # keeps the backward recompute from replaying the collective
    out = checkpoint_name(out, "attn_proj_out")
    if return_kv:
        return out, (k, v)
    return out


# ---- decode with KV cache ----------------------------------------------------

def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    """Ring-buffer cache when sliding_window > 0 (window-sized), else full."""
    size = min(max_len, cfg.sliding_window) if cfg.sliding_window > 0 else max_len
    shape = (batch, size, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(params, x, cache, index, cfg,
                     *, constrain: Constrain = no_constrain):
    """One-token decode. x: (B, 1, d); index: scalar absolute position, or a
    (B,) int32 vector of per-row positions (continuous batching: every cache
    row advances independently; see repro/serve/engine.py).

    Returns (out (B,1,d), new_cache). Sliding-window caches are ring buffers
    indexed by ``position % window`` per row. Writes whose position falls
    outside a full cache are dropped (the row's slot budget is exhausted).
    """
    B, _, d = x.shape
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32), (B,))
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, 1, nh, hd)
    k = (x @ params["wk"].astype(x.dtype)).reshape(B, 1, nkv, hd)
    v = (x @ params["wv"].astype(x.dtype)).reshape(B, 1, nkv, hd)
    pos = idx[:, None]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    size = cache["k"].shape[1]
    ring = cfg.sliding_window > 0
    slot = idx % size if ring else idx
    rows = jnp.arange(B)
    ck = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype),
                                       mode="drop")
    cv = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype),
                                       mode="drop")
    new_cache = {"k": ck, "v": cv}

    o = slot_decode_attention_ref(q[:, 0], ck, cv, idx, ring=ring)
    o = o.reshape(B, 1, nh * hd).astype(x.dtype)
    out = o @ params["wo"].astype(x.dtype)
    return constrain(out, "act_btd"), new_cache


# ----------------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------------

def init_mlp(rng, d: int, d_ff: int, activation: str) -> dict:
    ks = jax.random.split(rng, 3)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(d_ff)
    p = {"up": jax.random.normal(ks[0], (d, d_ff), jnp.float32) * s_in,
         "down": jax.random.normal(ks[1], (d_ff, d), jnp.float32) * s_out}
    if activation == "swiglu":
        p["gate"] = jax.random.normal(ks[2], (d, d_ff), jnp.float32) * s_in
    return p


def apply_mlp(params, x, activation: str,
              constrain: Constrain = no_constrain):
    up = x @ params["up"].astype(x.dtype)
    up = constrain(up, "act_ff")
    if activation == "swiglu":
        gate = constrain(x @ params["gate"].astype(x.dtype), "act_ff")
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = checkpoint_name(h, "mlp_hidden")
    out = h @ params["down"].astype(x.dtype)
    return constrain(out, "act_btd")


# ----------------------------------------------------------------------------
# Embedding / LM head
# ----------------------------------------------------------------------------

def init_embedding(rng, vocab: int, d: int) -> dict:
    return {"table": jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02}


def embed(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]


def unembed(params, x):
    return x @ params["table"].T.astype(x.dtype)
