"""Collective census of a lowered+compiled train step (Shardlint layer 1).

For a ParallelPlan spec this builds the train step the trainer would run
(``make_train_step(..., plan=...)``), lowers it on sharded
ShapeDtypeStruct stand-ins (``launch/specs.py`` — zero allocation), and
walks both representations:

* the **jaxpr** (:func:`jaxpr_census`) — primitive counts with a
  ``/manual`` suffix inside shard_map regions, which is where the
  ``ragged_dot``-reaches-GSPMD and stray-callback contracts look;
* the **compiled HLO** (:func:`hlo_census`) — per-collective-kind counts,
  ring-model bytes and max single payload, through the same
  :func:`repro.launch.roofline.walk_collectives` pass the roofline uses,
  so census bytes and roofline bytes can never diverge.

The entry also records the analytic expectation from ``launch/costmodel``
and the full fp32 parameter bytes (the ``epso-no-full-param-gather``
threshold), then runs the plan's declared contracts
(:mod:`repro.analysis.contracts`).

The committed 4-plan matrix baseline:

    PYTHONPATH=src python -m repro.analysis.census --matrix \\
        --out ANALYSIS_census.json

is gated by ``benchmarks/check_regression.py`` (exact per-kind counts,
bytes within tolerance, zero contract violations), so a GSPMD behavior
change across the jax version matrix fails CI with a readable diff.

Uses a reduced mula-7b-a1b (d_model=64, seq=32, batch=8): small enough to
compile in ~10s/plan on the CI CPU, big enough that every collective the
full model emits (EP dispatch, expert-TP, EPSO ring, pp loop) appears.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.analysis import contracts as C

# The committed plan matrix (ANALYSIS_census.json). pp needs mb divisible
# by stages; the two overlap variants pin the EPSO gather/scatter overlap
# ring on and off so a regression in either path is caught structurally.
MATRIX = (
    "dp=8",
    "dp=2,pp=2,ep=2,opt=epso,mb=4",
    "dp=2,ep=2,tp=2,opt=epso,overlap=ring",
    "dp=2,ep=2,tp=2,opt=epso,overlap=off",
    # rebalance= plans are lowered under a deterministic non-identity
    # expert placement (reversed rows) so the placed dispatch path and the
    # placement-consistency contract are exercised structurally
    "dp=2,ep=2,tp=2,opt=epso,overlap=ring,rebalance=50:1.25",
)

# jaxpr primitives worth keeping in the baseline: the contract inputs
# (ragged_dot, callbacks) plus the collectives that tell overlap-ring
# apart from overlap-off. Everything else churns across jax versions
# without meaning anything for sharding.
_INTERESTING = ("ragged_dot", "callback", "shard_map", "ppermute",
                "all_gather", "all_to_all", "psum", "reduce_scatter",
                "infeed", "outfeed")


def jaxpr_census(closed_jaxpr) -> dict:
    """Count primitives in a ClosedJaxpr, recursing into sub-jaxprs in
    equation params; primitives inside a ``shard_map`` get a ``/manual``
    suffix (collectives there are hand-placed, not GSPMD-inserted)."""
    prims: dict = {}

    def walk(jx, manual):
        for eqn in jx.eqns:
            name = eqn.primitive.name
            key = name + ("/manual" if manual else "")
            prims[key] = prims.get(key, 0) + 1
            man = manual or name == "shard_map"
            for v in eqn.params.values():
                for x in (v if isinstance(v, (list, tuple)) else (v,)):
                    if hasattr(x, "jaxpr"):
                        walk(x.jaxpr, man)
                    elif hasattr(x, "eqns"):
                        walk(x, man)

    walk(closed_jaxpr.jaxpr, False)
    return prims


def interesting_prims(prims: dict) -> dict:
    return {k: v for k, v in sorted(prims.items())
            if any(s in k for s in _INTERESTING)}


def hlo_census(hlo_text: str) -> dict:
    """Counts / ring-model bytes / max single payload per collective kind,
    plus host-transfer instructions — one pass over the compiled HLO via
    the shared roofline walker."""
    from repro.launch import roofline as RL
    counts = {k: 0 for k in RL.COLLECTIVE_KINDS}
    ring = {k: 0.0 for k in RL.COLLECTIVE_KINDS}
    max_payload = {k: 0 for k in RL.COLLECTIVE_KINDS}
    unknown: set = set()
    for instr in RL.walk_collectives(hlo_text, unknown):
        counts[instr.kind] += 1
        ring[instr.kind] += instr.ring_bytes
        max_payload[instr.kind] = max(max_payload[instr.kind],
                                      instr.result_bytes)
    ring["total"] = sum(v for k, v in ring.items() if k != "total")
    host = []
    for line in hlo_text.splitlines():
        if C.is_host_transfer_line(line):
            host.append(line.strip()[:160])
    return {"counts": counts, "ring_bytes": ring,
            "max_payload": max_payload, "host_transfers": host,
            "unknown_dtypes": sorted(unknown)}


def full_param_bytes(cfg) -> int:
    """Total fp32 master-parameter bytes (shape-only eval)."""
    import jax
    import numpy as np
    from repro.models import init_params
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    return int(sum(int(np.prod(l.shape)) * 4
                   for l in jax.tree.leaves(shapes)))


def collect_plan_census(spec: str, *, arch: str = "mula-7b-a1b",
                        d_model: int = 64, seq: int = 32,
                        batch: int = 8) -> dict:
    """Build + lower + compile the train step for ``spec`` and return its
    census entry (JSON-ready dict), contracts already evaluated.

    Needs the plan's device count forced onto the CPU backend *before*
    backend init (``launch.mesh.ensure_host_devices`` / the mesh8 test
    fixture / the census CLI all arrange this)."""
    import jax
    from repro.configs import TrainConfig, get_config, reduced
    from repro.configs.base import InputShape
    from repro.launch.specs import input_specs, state_specs
    from repro.parallel.plan import ParallelPlan
    from repro.train import make_train_step

    cfg = reduced(get_config(arch), d_model=d_model)
    tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                     grad_reduce_dtype="float32", seq_len=seq,
                     global_batch=batch)
    pplan = ParallelPlan.parse(spec)
    cfg = pplan.apply_to_model(cfg)
    plan = pplan.resolve(cfg, global_batch=batch)
    placement = None
    if pplan.rebalance_params() is not None and cfg.moe is not None:
        # lower under a deterministic non-identity placement: the step a
        # rebalancing run actually executes mid-schedule (reversed expert
        # order is the worst-case non-trivial permutation)
        from repro.parallel.placement import ExpertPlacement
        ne = cfg.moe.num_experts
        placement = ExpertPlacement.broadcast(
            tuple(reversed(range(ne))), cfg.num_layers)
        plan = plan.with_placement(placement)
    step = make_train_step(cfg, None, tc, plan=plan)

    shape = InputShape("census", seq, batch, "train")
    opt_mode = plan.opt_shard if plan.mesh is not None else "none"
    state = state_specs(cfg, tc, plan.rules, opt_mode)
    bat = input_specs(cfg, shape, plan.rules)

    if not hasattr(step, "lower"):
        step = jax.jit(step)
    t0 = time.time()
    lowered = step.lower(state, bat)
    prims = jaxpr_census(jax.make_jaxpr(step)(state, bat))
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    entry = {
        "spec": str(pplan),
        "arch": arch,
        "mesh": {} if plan.mesh is None else
                {k: int(v) for k, v in plan.mesh.shape.items()},
        "devices": pplan.num_devices,
        "opt_overlap_impl": getattr(step, "opt_overlap_impl", None),
        "full_param_bytes": full_param_bytes(cfg),
        "jaxpr_prims": interesting_prims(prims),
        "contracts": list(pplan.contracts()),
        "moe_experts": cfg.moe.num_experts if cfg.moe is not None else None,
        "lower_s": round(t1 - t0, 1),
        "compile_s": round(t2 - t1, 1),
    }
    if placement is not None:
        entry["placement"] = {
            "num_experts": placement.num_experts,
            "num_layers": placement.num_layers,
            "identity": placement.is_identity,
            "is_permutation": all(
                sorted(row) == list(range(placement.num_experts))
                for row in placement.perm),
        }
    entry.update(hlo_census(compiled.as_text()))

    entry["analytic_total"] = 0.0
    if plan.mesh is not None:
        from repro.launch import costmodel as CM
        # the analytic probes shard the per-microbatch batch over the batch
        # axes — clamp nmb the same way dryrun.lower_one does
        nmb = pplan.microbatches
        shards = 1
        for a in plan.rules.batch_axes:
            shards *= plan.mesh.shape[a]
        while nmb > 1 and batch % (nmb * shards) != 0:
            nmb //= 2
        cm = CM.analyze(cfg, shape, plan.rules, opt_mode=opt_mode,
                        microbatches=nmb)
        entry["analytic_total"] = float(
            cm["coll_per_chip"].get("total", 0.0))
    entry["violations"] = C.violations(entry)
    return entry


def run_matrix(specs=MATRIX, *, log=print, **kw) -> dict:
    """Census every plan in ``specs`` -> the ANALYSIS_census.json payload
    (``census_points`` + a ``meta`` block recording the jax versions the
    baseline was produced on)."""
    import jax
    import jaxlib
    points = []
    for spec in specs:
        log(f"[census] {spec} ...")
        e = collect_plan_census(spec, **kw)
        log(f"[census] {spec}: " + ", ".join(
            f"{k}={v}" for k, v in e["counts"].items() if v) +
            f", ring_total={e['ring_bytes']['total']:.3e}" +
            (f", VIOLATIONS={len(e['violations'])}" if e["violations"]
             else ""))
        points.append(e)
    return {
        "meta": {
            "jax": jax.__version__,
            "jaxlib": getattr(jaxlib, "__version__", "?"),
            "arch": kw.get("arch", "mula-7b-a1b"),
            "d_model": kw.get("d_model", 64),
            "seq_len": kw.get("seq", 32),
            "global_batch": kw.get("batch", 8),
        },
        "census_points": points,
    }


def format_entry(e: dict) -> str:
    """Human-readable one-plan census block (dryrun --analyze output)."""
    lines = [f"== collective census: {e['spec']} =="]
    mesh = " x ".join(f"{k}={v}" for k, v in (e.get("mesh") or {}).items())
    lines.append(f"mesh     : {mesh or 'none (single device)'}"
                 f"  overlap_impl={e.get('opt_overlap_impl')}")
    lines.append(f"{'kind':20s} {'count':>6s} {'ring bytes':>12s} "
                 f"{'max payload':>12s}")
    for k in sorted(e["counts"]):
        if e["counts"][k]:
            lines.append(f"{k:20s} {e['counts'][k]:6d} "
                         f"{e['ring_bytes'][k]:12.3e} "
                         f"{e['max_payload'][k]:12d}")
    tot = e["ring_bytes"]["total"]
    an = e.get("analytic_total") or 0.0
    ratio = f" (x{tot / an:.2f} of analytic {an:.3e})" if an else ""
    lines.append(f"ring-model total: {tot:.3e} B/device{ratio}")
    lines.append(f"full fp32 param bytes: {e['full_param_bytes']}")
    if e.get("jaxpr_prims"):
        lines.append("jaxpr: " + ", ".join(
            f"{k}={v}" for k, v in sorted(e["jaxpr_prims"].items())))
    for cid in e.get("contracts", []):
        lines.append(f"contract {cid:28s} "
                     f"{'FAIL' if any(cid in v for v in e['violations']) else 'ok'}")
    for v in e.get("violations", []):
        lines.append(f"VIOLATION: {v}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.census",
        description="collective census + sharding-contract check of "
                    "lowered train steps")
    ap.add_argument("--plan", action="append", default=None,
                    help="ParallelPlan spec to census (repeatable)")
    ap.add_argument("--matrix", action="store_true",
                    help=f"census the committed baseline matrix: "
                         f"{'; '.join(MATRIX)}")
    ap.add_argument("--arch", default="mula-7b-a1b")
    ap.add_argument("--out", default=None,
                    help="write the census JSON here (the baseline file)")
    args = ap.parse_args(argv)

    specs = list(args.plan or [])
    if args.matrix or not specs:
        specs = list(MATRIX)

    # the plans run in-process: force enough host devices before the
    # backend wakes up (no-op if the caller already set XLA_FLAGS)
    from repro.launch.mesh import ensure_host_devices
    from repro.parallel.plan import ParallelPlan
    ensure_host_devices(max(ParallelPlan.parse(s).num_devices
                            for s in specs))

    data = run_matrix(specs, arch=args.arch)
    print()
    for e in data["census_points"]:
        print(format_entry(e))
        print()
    if args.out:
        with open(args.out, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")
    nviol = sum(len(e["violations"]) for e in data["census_points"])
    if nviol:
        print(f"census: {nviol} contract violation(s)", file=sys.stderr)
        return 1
    print(f"census ok: {len(data['census_points'])} plan(s), "
          f"all contracts hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
