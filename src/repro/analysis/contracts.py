"""Sharding contracts — declarative properties of a lowered train step.

Each contract is a named predicate over a *census entry* (the plain dict
``repro.analysis.census.collect_plan_census`` produces and
``ANALYSIS_census.json`` stores), so the same checks run on a freshly
traced step and on a loaded baseline file. A check returns a list of
violation strings (empty = holds); every message leads with the contract
id so CI output and the injection tests can flag failures *by name*.

Which contracts apply to a plan is declared by
``ParallelPlan.contracts()`` — the plan is the single source of truth for
its own invariants, the same way it owns mesh axes and kernel knobs.

The registry (see ARCHITECTURE.md for the incident behind each rule):

===========================  ==============================================
id                           property of the lowered program
===========================  ==============================================
epso-no-full-param-gather    under ``opt=epso`` no single all-gather's
                             payload reaches the full fp32 parameter
                             bytes — the PR 7 regression (eager GSPMD
                             update tail re-gathering every master shard)
                             expressed structurally instead of as a
                             step-time delta
no-gspmd-ragged-dot          no ``ragged_dot`` primitive outside a manual
                             (shard_map) region: XLA's SPMD partitioner
                             rewrites ragged_dot's group_sizes operand
                             incorrectly on ep/tp meshes (PR 6)
no-host-transfer             no infeed/outfeed/send/recv or host-callback
                             custom-calls inside the step — a stray
                             ``jax.debug``/``device_get`` serializes every
                             step on the host sync
coll-vs-costmodel            measured collective bytes within ``tol``x of
                             ``launch/costmodel``'s analytic expectation
                             in either direction (a silent GSPMD behavior
                             change shows up here before it shows up as a
                             mystery slowdown)
placement-consistency        a plan that declares ``rebalance=`` carries
                             expert-placement metadata and the recorded
                             permutation is a true bijection over the
                             expert ids (parallel/placement.py) — a
                             non-permutation would silently duplicate or
                             drop experts at dispatch time
===========================  ==============================================
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List

# measured census / analytic-costmodel byte ratio must stay inside
# [1/tol, tol]. The matrix plans sit at 0.52-0.58 on the reference jax
# (the analytic model charges idealized per-chip rings; GSPMD emits fewer,
# larger fused collectives), so the ISSUE's 2x would sit right on the
# boundary — 3x keeps the gate meaningful without flapping.
COSTMODEL_TOLERANCE = 3.0

# HLO custom-call targets / instruction substrings that move data to the
# host. Plain custom-calls (TopK & friends) are device-side and benign —
# matching all of them would false-positive every top-k router.
_HOST_CC_PATTERNS = ("callback", "xla_python", "host", "infeed", "outfeed")


@dataclass(frozen=True)
class Contract:
    """One sharding contract: id, what it guards, and the check."""
    id: str
    description: str
    check: Callable[[dict], List[str]]


CONTRACTS: Dict[str, Contract] = {}


def _register(cid: str, description: str):
    def deco(fn):
        CONTRACTS[cid] = Contract(cid, description, fn)
        return fn
    return deco


@_register("epso-no-full-param-gather",
           "EPSO: no all-gather whose payload reaches full-param bytes")
def _epso_no_full_param_gather(entry: dict) -> List[str]:
    fp = entry.get("full_param_bytes", 0)
    if not fp:
        return []
    mx = (entry.get("max_payload") or {}).get("all-gather", 0)
    if mx >= fp:
        return [f"epso-no-full-param-gather: all-gather payload {mx} B >= "
                f"full fp32 param bytes {fp} B — the optimizer is "
                f"re-materializing unsharded masters (plan "
                f"{entry.get('spec', '?')!r}); the bucketed overlap path "
                f"moves shards with ppermute rings, never a full gather"]
    return []


@_register("no-gspmd-ragged-dot",
           "no ragged_dot primitive outside a manual shard_map region")
def _no_gspmd_ragged_dot(entry: dict) -> List[str]:
    prims = entry.get("jaxpr_prims") or {}
    bad = {k: v for k, v in prims.items()
           if "ragged_dot" in k and not k.endswith("/manual")}
    return [f"no-gspmd-ragged-dot: {k} traced {v}x in GSPMD (auto) "
            f"context on plan {entry.get('spec', '?')!r} — the SPMD "
            f"partitioner corrupts its group_sizes operand on ep/tp "
            f"meshes; route through kernels.ops or a manual region"
            for k, v in sorted(bad.items())]


@_register("no-host-transfer",
           "no host transfers or callbacks inside the traced step")
def _no_host_transfer(entry: dict) -> List[str]:
    out = [f"no-host-transfer: HLO host transfer in step: {t}"
           for t in entry.get("host_transfers") or []]
    prims = entry.get("jaxpr_prims") or {}
    out += [f"no-host-transfer: callback primitive {k} traced {v}x "
            f"inside the step"
            for k, v in sorted(prims.items()) if "callback" in k]
    return out


@_register("coll-vs-costmodel",
           f"census bytes within {COSTMODEL_TOLERANCE}x of the analytic "
           f"cost model")
def _coll_vs_costmodel(entry: dict) -> List[str]:
    analytic = entry.get("analytic_total") or 0.0
    measured = (entry.get("ring_bytes") or {}).get("total", 0.0)
    if analytic <= 0 or measured <= 0:
        return []
    ratio = measured / analytic
    tol = entry.get("costmodel_tol") or COSTMODEL_TOLERANCE
    if ratio > tol or ratio < 1.0 / tol:
        return [f"coll-vs-costmodel: measured collective bytes "
                f"{measured:.3e} vs analytic {analytic:.3e} "
                f"(ratio {ratio:.2f}) diverge beyond {tol}x on plan "
                f"{entry.get('spec', '?')!r}"]
    return []


@_register("placement-consistency",
           "rebalance= plans carry a bijective expert-placement record")
def _placement_consistency(entry: dict) -> List[str]:
    spec = entry.get("spec", "?")
    pl = entry.get("placement")
    if pl is None:
        return [f"placement-consistency: plan {spec!r} declares rebalance= "
                f"but the census entry carries no placement record — the "
                f"lowered step's expert placement is unaccounted for"]
    out = []
    ne = pl.get("num_experts")
    if not pl.get("is_permutation"):
        out.append(f"placement-consistency: recorded placement on plan "
                   f"{spec!r} is not a bijection over {ne} experts — "
                   f"dispatch would duplicate/drop experts")
    moe = entry.get("moe_experts")
    if moe and ne and moe != ne:
        out.append(f"placement-consistency: placement covers {ne} experts "
                   f"but the model routes over {moe} (plan {spec!r})")
    return out


def is_host_transfer_line(line: str) -> bool:
    """True for an HLO instruction line that moves data to/from the host:
    infeed/outfeed/send/recv ops, or a custom-call whose target matches a
    host/callback pattern. Used by the census's HLO walk."""
    s = line.strip()
    if " = " not in s:
        return False
    body = s.split(" = ", 1)[1]
    head = body.split("(", 1)[0].strip().split() if "(" in body else []
    op = head[-1] if head else ""
    base = op.split("-start")[0].split("-done")[0]
    if base in ("infeed", "outfeed", "send", "recv"):
        return True
    if "custom-call" in body and "custom_call_target=" in body:
        target = body.split("custom_call_target=", 1)[1][:120].lower()
        return any(p in target for p in _HOST_CC_PATTERNS)
    return False


def check_entry(entry: dict, ids=None) -> Dict[str, List[str]]:
    """Run contracts against one census entry.

    ``ids`` defaults to the entry's own ``contracts`` list (what the plan
    declared at collection time), falling back to every registered
    contract. Returns {contract_id: [violation, ...]} with every requested
    id present (empty list = contract holds). Unknown ids raise — a
    baseline naming a contract this build doesn't know is itself a drift.
    """
    if ids is None:
        ids = entry.get("contracts") or sorted(CONTRACTS)
    out: Dict[str, List[str]] = {}
    for cid in ids:
        if cid not in CONTRACTS:
            raise KeyError(f"unknown sharding contract {cid!r}; registered: "
                           f"{', '.join(sorted(CONTRACTS))}")
        out[cid] = CONTRACTS[cid].check(entry)
    return out


def violations(entry: dict, ids=None) -> List[str]:
    """Flat list of violation strings for ``entry`` (see check_entry)."""
    out: List[str] = []
    for _, msgs in sorted(check_entry(entry, ids).items()):
        out.extend(msgs)
    return out
