"""Repo AST lint (Shardlint layer 2) — ``python -m repro.analysis.lint``.

Encodes the repo's hard-won sharding rules as checkable code. Pure
stdlib (``ast``) on purpose: CI's lint job runs it before any jax wheel
is installed, and it must stay runnable on a bare interpreter.

=====  ====================================================================
rule   what it forbids (and the incident behind it)
=====  ====================================================================
SL001  importing ``jax.experimental.shard_map`` anywhere but
       ``compat.py`` — ``compat.manual_shard_map`` owns the 0.4.x
       partial-auto shims; a raw import silently loses them
SL002  ``ragged_dot`` outside the documented allowlist
       (``kernels/ref.py``) — XLA's SPMD partitioner rewrites its
       group_sizes operand incorrectly on ep/tp meshes (PR 6)
SL003  ``jax.device_get`` / ``np.asarray`` inside traced step-building
       modules (train/ models/ optim/ parallel/ core/) — a host sync
       baked into the step serializes every iteration
SL004  any occurrence of the retired ``KERNEL_CONFIG`` / ``ATTN_IMPL``
       aliases — plan-scoped ``KernelPlan`` replaced the process-global
       knobs, and the PR 4 compatibility shims are now deleted; reads,
       writes, and imports alike are tombstoned (no allowlist)
=====  ====================================================================

Allowlists are path *suffixes* (posix-normalized), so the lint works on
absolute or relative invocations. A synthetic file outside the repo gets
no allowlist match — which is exactly what the CI self-test relies on.
"""
from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path
from typing import Iterator, List, Tuple

# rule -> path suffixes where the construct is the documented owner
ALLOWLIST = {
    "SL001": ("src/repro/compat.py",),
    "SL002": ("src/repro/kernels/ref.py",),
    "SL003": (),
    # SL004 has no owners left: the PR 4 aliases are deleted, the symbols
    # are tombstones — any mention (read, write, or import) is a violation
    "SL004": (),
}

# SL003 applies only inside modules whose code ends up in the traced step
TRACED_MODULE_DIRS = ("src/repro/train/", "src/repro/models/",
                      "src/repro/optim/", "src/repro/parallel/",
                      "src/repro/core/")

_DEPRECATED_ALIASES = ("KERNEL_CONFIG", "ATTN_IMPL")

Violation = Tuple[str, str, int, str]     # (rule, path, lineno, message)


def _dotted(node) -> str:
    """'jax.experimental.shard_map' for an Attribute/Name chain ('' when
    the chain bottoms out in something dynamic)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _allowed(rule: str, posix_path: str, extra=()) -> bool:
    return any(posix_path.endswith(sfx)
               for sfx in tuple(ALLOWLIST.get(rule, ())) + tuple(extra))


def _np_aliases(tree: ast.AST) -> set:
    """Module-level names bound to the numpy module ('np', 'numpy')."""
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    names.add(a.asname or "numpy")
    return names


def lint_source(source: str, path: str, *,
                traced_dirs=TRACED_MODULE_DIRS,
                allow_extra=()) -> List[Violation]:
    """Lint one file's source. ``path`` is used for allowlist matching and
    reporting only. ``traced_dirs`` scopes SL003 (tests override it to
    force a synthetic file into 'traced' territory)."""
    posix = Path(path).as_posix()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [("SL000", path, e.lineno or 0, f"syntax error: {e.msg}")]

    out: List[Violation] = []
    is_traced = any(d in posix for d in traced_dirs)
    np_names = _np_aliases(tree)

    def emit(rule, node, msg):
        if not _allowed(rule, posix, allow_extra):
            out.append((rule, path, getattr(node, "lineno", 0), msg))

    for node in ast.walk(tree):
        # SL001 — raw shard_map imports
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.startswith("jax.experimental.shard_map"):
                    emit("SL001", node,
                         f"import {a.name}: use compat.manual_shard_map "
                         f"(owns the partial-auto shims)")
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod.startswith("jax.experimental.shard_map"):
                emit("SL001", node,
                     f"from {mod} import ...: use "
                     f"compat.manual_shard_map")
            elif mod == "jax.experimental" and any(
                    a.name == "shard_map" for a in node.names):
                emit("SL001", node,
                     "from jax.experimental import shard_map: use "
                     "compat.manual_shard_map")

        dotted = _dotted(node) if isinstance(node, ast.Attribute) else ""

        # SL001 — attribute use without import (jax.experimental.shard_map.x)
        if dotted.startswith("jax.experimental.shard_map"):
            emit("SL001", node,
                 f"{dotted}: use compat.manual_shard_map")

        # SL002 — ragged_dot outside the allowlist
        if isinstance(node, ast.Attribute) and node.attr == "ragged_dot":
            emit("SL002", node,
                 f"{dotted or 'ragged_dot'}: GSPMD corrupts ragged_dot's "
                 f"group_sizes on ep/tp meshes — use kernels.ops.gmm or "
                 f"extend the SL002 allowlist with a justification")

        # SL003 — host transfers in traced step-building modules
        if is_traced and isinstance(node, ast.Attribute):
            if dotted == "jax.device_get":
                emit("SL003", node,
                     "jax.device_get inside a traced step-building "
                     "module: host sync per step")
            elif node.attr == "asarray" and dotted and \
                    dotted.split(".")[0] in np_names:
                emit("SL003", node,
                     f"{dotted}: numpy materialization inside a traced "
                     f"step-building module (use jnp.asarray)")

        # SL004 — ANY occurrence of the retired module-global kernel knobs:
        # bare names, attribute access (ops.KERNEL_CONFIG), and imports.
        # The aliases are deleted; a surviving mention is dead code that
        # would NameError (or worse, resurrect the global) at runtime.
        if isinstance(node, ast.Name) and node.id in _DEPRECATED_ALIASES:
            emit("SL004", node,
                 f"{node.id} is retired: scope kernel knobs with "
                 f"KernelPlan / use_kernel_plan instead")
        elif isinstance(node, ast.Attribute) and \
                node.attr in _DEPRECATED_ALIASES:
            emit("SL004", node,
                 f"{_dotted(node) or node.attr} is retired: scope kernel "
                 f"knobs with KernelPlan / use_kernel_plan instead")
        elif isinstance(node, ast.ImportFrom) and any(
                a.name in _DEPRECATED_ALIASES for a in node.names):
            emit("SL004", node,
                 "importing a retired alias (KERNEL_CONFIG/ATTN_IMPL): "
                 "scope kernel knobs with KernelPlan / use_kernel_plan")
    return out


def iter_py_files(paths) -> Iterator[Path]:
    for p in paths:
        p = Path(p)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "__pycache__" not in f.parts:
                    yield f
        elif p.suffix == ".py":
            yield p


def lint_paths(paths, *, traced_dirs=TRACED_MODULE_DIRS,
               allow_extra=()) -> List[Violation]:
    out: List[Violation] = []
    for f in iter_py_files(paths):
        try:
            src = f.read_text()
        except (OSError, UnicodeDecodeError) as e:
            out.append(("SL000", str(f), 0, f"unreadable: {e}"))
            continue
        out.extend(lint_source(src, str(f), traced_dirs=traced_dirs,
                               allow_extra=allow_extra))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Shardlint AST rules SL001-SL004 (stdlib-only)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src tests "
                         "benchmarks under the cwd)")
    ap.add_argument("--traced", action="append", default=None,
                    help="extra path fragment treated as a traced "
                         "step-building module for SL003 (tests use this "
                         "on synthetic files)")
    ap.add_argument("--allow", action="append", default=None,
                    help="extra allowlisted path suffix (all rules)")
    args = ap.parse_args(argv)

    paths = args.paths or [p for p in ("src", "tests", "benchmarks")
                           if Path(p).is_dir()]
    if not paths:
        print("shardlint: no paths to lint", file=sys.stderr)
        return 2
    traced = TRACED_MODULE_DIRS + tuple(args.traced or ())
    vs = lint_paths(paths, traced_dirs=traced,
                    allow_extra=tuple(args.allow or ()))
    for rule, path, lineno, msg in vs:
        print(f"{path}:{lineno}: {rule} {msg}")
    n = sum(1 for v in vs)
    files = sum(1 for _ in iter_py_files(paths))
    if n:
        print(f"shardlint: {n} violation(s) in {files} file(s)",
              file=sys.stderr)
        return 1
    print(f"shardlint: {files} file(s) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
