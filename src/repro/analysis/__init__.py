"""Shardlint — static analysis for traced train steps and repo source.

Two layers (ISSUE 9 / ARCHITECTURE.md "Static analysis"):

* **Trace analysis** (:mod:`repro.analysis.census`): lower + compile the
  train step for a ParallelPlan on shape stand-ins (zero allocation),
  walk the compiled HLO with :func:`repro.launch.roofline.walk_collectives`
  and the jaxpr with :func:`repro.analysis.census.jaxpr_census`, and check
  the resulting *collective census* against the plan's declared
  **sharding contracts** (:mod:`repro.analysis.contracts`) and the
  analytic cost model. Baselines live in ``ANALYSIS_census.json`` and are
  gated by ``benchmarks/check_regression.py`` like the BENCH files.

* **AST lint** (:mod:`repro.analysis.lint`): dependency-free source rules
  (``SL001``–``SL004``) encoding the repo's hard-won sharding lessons —
  raw ``shard_map`` imports, ``ragged_dot`` outside its allowlist, host
  transfers inside traced step-building modules, writers to the
  deprecated kernel-config aliases. Runs in CI's lint job without jax.
"""
from repro.analysis.contracts import CONTRACTS, check_entry  # noqa: F401
