from .trainer import (TrainState, make_train_step, make_serve_step,
                      make_prefill_step, init_state, train_state_shardings)
