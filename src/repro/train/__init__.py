from .trainer import TrainState, make_train_step, make_serve_step, init_state
