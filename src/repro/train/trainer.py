"""Training / serving steps.

``train_step`` implements the paper's recipe (§2.1): bf16 fwd/bwd on bf16
params, bf16 gradient reduction, fp32 master weights + AdamW states (held in
the optimizer state, sharded per SO/EPSO), warmup+cosine LR, global-norm
clipping enabled only after warmup, gradient accumulation over microbatches
via ``lax.scan``, SAC remat policies.

``serve_step`` is single-token decode against a KV/SSM cache (the lowering
target for decode_32k / long_500k) — with ``sample=True`` it becomes the
serve engine's decode lowering (per-slot positions + per-request sampling;
repro/serve/engine.py). ``prefill_step`` is the forward pass for prefill_32k;
with ``into_cache=True`` it writes prompt K/V straight into cache slots (the
engine's admission path).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.models import (init_params, loss_fn, forward, init_cache,
                          decode_step, prefill_with_cache)
from repro.optim import adamw_init, adamw_update, warmup_cosine, AdamWState
from repro.optim.epso import optimizer_state_shardings
from repro.parallel.sharding import make_rules, shardings as param_shardings


class TrainState(NamedTuple):
    params: dict          # compute-precision params (bf16 in production)
    opt: AdamWState       # fp32 master + moments


def train_state_shardings(params, rules, mode: str = "none"):
    """TrainState-shaped NamedSharding pytree: params per ``param_specs``,
    AdamW master/m/v per ``optimizer_state_specs(mode)`` (paper §3.2 SO/EPSO
    placement), the step counter replicated. ``params`` may be concrete
    arrays or ShapeDtypeStructs — only shapes are read. Returns None off-mesh.
    """
    if rules is None or rules.mesh is None:
        return None
    psh = param_shardings(params, rules)
    osh = optimizer_state_shardings(params, rules, mode)
    rep = NamedSharding(rules.mesh, P())
    return TrainState(psh, AdamWState(rep, osh, osh, osh))


def _resolve_rules(cfg, train, rules, mesh):
    if rules is None and mesh is not None:
        rules = make_rules(cfg, mesh, kind="train",
                           global_batch=train.global_batch)
    return rules


def init_state(rng, cfg: ModelConfig, train: TrainConfig, *, rules=None,
               mesh=None, opt_sharding_mode: str = "none") -> TrainState:
    """Initialize params + AdamW state. With ``rules``/``mesh``, every leaf
    is device_put onto its SO/EPSO sharding right after host init, so the
    first jitted step sees exactly the placement it was compiled for. (The
    state is still materialized on one device first — models that only fit
    sharded would jit init with these shardings as ``out_shardings``.)"""
    rules = _resolve_rules(cfg, train, rules, mesh)
    params = init_params(rng, cfg)
    opt = adamw_init(params)
    pd = jnp.dtype(train.param_dtype)
    params = jax.tree.map(lambda p: p.astype(pd), params)
    state = TrainState(params, opt)
    sh = train_state_shardings(params, rules, opt_sharding_mode)
    if sh is not None:
        state = jax.tree.map(jax.device_put, state, sh)
    return state


def make_train_step(cfg: ModelConfig, parallel: ParallelConfig,
                    train: TrainConfig, *, rules=None, mesh=None,
                    opt_sharding_mode: Optional[str] = None,
                    state_shardings=None):
    """Build the train step. With ``opt_sharding_mode`` set ('none'|'so'|
    'epso') the step is returned jitted with the optimizer-state shardings as
    ``out_shardings`` — XLA derives the paper's reduce-scatter (grads into
    state shards) and all-gather (updated params) from the placement
    mismatch. A caller that already holds the ``train_state_shardings`` tree
    can pass it as ``state_shardings`` to skip the abstract init re-trace.
    With ``opt_sharding_mode=None`` (default) the raw function is returned
    and the caller jits it (legacy single-device path)."""
    rules = _resolve_rules(cfg, train, rules, mesh)
    if mesh is None and rules is not None:
        mesh = rules.mesh
    cd = jnp.dtype(train.compute_dtype)
    pd = jnp.dtype(train.param_dtype)
    rd = jnp.dtype(train.grad_reduce_dtype)
    nmb = parallel.microbatches

    def loss_for(params, mb):
        return loss_fn(params, mb, cfg, rules=rules, mesh=mesh,
                       sac=parallel.remat_policy, compute_dtype=cd)

    def train_step(state: TrainState, batch: dict):
        params = state.params

        if nmb > 1:
            def split(x):
                return x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                gacc, lacc, macc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_for, has_aux=True)(params, mb)
                gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                    gacc, grads)
                return (gacc, lacc + loss, macc + metrics["ce"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss, ce), _ = jax.lax.scan(
                acc_step, (g0, jnp.zeros(()), jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            loss, ce = loss / nmb, ce / nmb
            metrics = {"ce": ce}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)

        # paper: bf16 gradient reduction (cast before the DP reduction that
        # XLA derives from the state shardings), fp32 update
        grads = jax.tree.map(lambda g: g.astype(rd).astype(jnp.float32),
                             grads)

        lr = warmup_cosine(state.opt.step, lr_peak=train.lr_peak,
                           lr_min=train.lr_min,
                           warmup_steps=train.warmup_steps,
                           total_steps=train.total_steps)
        clip_on = None
        if train.clip_after_warmup_only:
            clip_on = state.opt.step >= train.warmup_steps
        new_params, new_opt, om = adamw_update(
            grads, state.opt, lr=lr, beta1=train.beta1, beta2=train.beta2,
            eps=train.eps, weight_decay=train.weight_decay,
            grad_clip=train.grad_clip, clip_enabled=clip_on, param_dtype=pd)
        out_metrics = {"loss": loss, "lr": lr, **metrics, **om}
        return TrainState(new_params, new_opt), out_metrics

    if opt_sharding_mode is None:
        return train_step
    if rules is None or rules.mesh is None:
        return jax.jit(train_step)
    ssh = state_shardings
    if ssh is None:
        shapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        ssh = train_state_shardings(shapes, rules, opt_sharding_mode)
    # metrics subtree: None = unconstrained (scalars; XLA replicates them)
    return jax.jit(train_step, out_shardings=(ssh, None))


def make_prefill_step(cfg: ModelConfig, *, rules=None, mesh=None,
                      compute_dtype=jnp.bfloat16, into_cache: bool = False):
    """``into_cache=False``: the prefill_32k lowering — forward over the
    batch, last-position logits. ``into_cache=True``: the serve engine's
    admission lowering — ``prefill_step(params, tokens, cache, slots,
    lengths)`` writes the prompts' K/V into the given cache slots and
    returns (last_logits, new_cache); see models.prefill_with_cache."""
    if into_cache:
        from repro.serve.engine import dropless_cfg
        scfg = dropless_cfg(cfg)   # serving must be batching-transparent

        def prefill_step(params, tokens, cache, slots, lengths):
            return prefill_with_cache(params, tokens, cache, slots, lengths,
                                      scfg, rules=rules, mesh=mesh,
                                      compute_dtype=compute_dtype)

        return prefill_step

    def prefill_step(params, batch):
        logits, _ = forward(params, batch, cfg, rules=rules, mesh=mesh,
                            sac="", compute_dtype=compute_dtype)
        return logits[:, -1]

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, rules=None,
                    compute_dtype=jnp.bfloat16, sample: bool = False):
    """``index`` may be a scalar (lockstep batch, the decode_32k shape) or a
    (B,) vector of per-slot positions (continuous batching). With
    ``sample=True`` returns the serve engine's full decode lowering —
    ``(params, tokens, cache, positions, seeds, temperature, top_k, top_p)
    -> (next_tokens, new_cache)`` — built by serve.make_decode_fn."""
    if sample:
        from repro.serve.engine import make_decode_fn
        return make_decode_fn(cfg, rules=rules, compute_dtype=compute_dtype)

    def serve_step(params, tokens, cache, index):
        return decode_step(params, tokens, cache, index, cfg, rules=rules,
                           compute_dtype=compute_dtype)

    return serve_step
