"""Training / serving steps.

``train_step`` implements the paper's recipe (§2.1): bf16 fwd/bwd on bf16
params, bf16 gradient reduction, fp32 master weights + AdamW states (held in
the optimizer state, sharded per SO/EPSO), warmup+cosine LR, global-norm
clipping enabled only after warmup, gradient accumulation over microbatches
via ``lax.scan``, SAC remat policies.

``serve_step`` is single-token decode against a KV/SSM cache (the lowering
target for decode_32k / long_500k) — with ``sample=True`` it becomes the
serve engine's decode lowering (per-slot positions + per-request sampling;
repro/serve/engine.py). ``prefill_step`` is the forward pass for prefill_32k;
with ``into_cache=True`` it writes prompt K/V straight into cache slots (the
engine's admission path).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.models import (init_params, loss_fn, forward,
                          decode_step, prefill_with_cache, embed_tokens,
                          pipeline_stage_forward, lm_head_ce, PP_ARCH_TYPES)
from repro.optim import adamw_init, adamw_update, warmup_cosine, AdamWState
from repro.optim.epso import optimizer_state_shardings, plan_update_buckets
from repro.optim.overlap import overlapped_adamw_update, resolve_opt_overlap
from repro.parallel.placement import expert_leaf_mask
from repro.parallel.pipeline import (check_pp_microbatches,
                                     pipelined_loss_and_grads,
                                     pipelined_loss_and_grads_per_stage,
                                     stack_stages)
from repro.parallel.plan import ResolvedPlan, use_kernel_plan
from repro.parallel.sharding import make_rules, shardings as param_shardings


class TrainState(NamedTuple):
    params: dict          # compute-precision params (bf16 in production)
    opt: AdamWState       # fp32 master + moments


def train_state_shardings(params, rules, mode: str = "none"):
    """TrainState-shaped NamedSharding pytree: params per ``param_specs``,
    AdamW master/m/v per ``optimizer_state_specs(mode)`` (paper §3.2 SO/EPSO
    placement), the step counter replicated. ``params`` may be concrete
    arrays or ShapeDtypeStructs — only shapes are read. Returns None off-mesh.
    """
    if rules is None or rules.mesh is None:
        return None
    psh = param_shardings(params, rules)
    osh = optimizer_state_shardings(params, rules, mode)
    rep = NamedSharding(rules.mesh, P())
    return TrainState(psh, AdamWState(rep, osh, osh, osh))


def _resolve_rules(cfg, train, rules, mesh):
    if rules is None and mesh is not None:
        rules = make_rules(cfg, mesh, kind="train",
                           global_batch=train.global_batch)
    return rules


def _unpack_plan(plan: Optional[ResolvedPlan], rules, mesh,
                 opt_sharding_mode):
    """A ResolvedPlan supplies rules/mesh/opt mode in one object; explicit
    kwargs (the legacy threading, now deprecated) win when both are given —
    an explicit ``opt_sharding_mode='none'`` disables sharding even
    alongside an EPSO plan (only ``None`` means 'take the plan's mode')."""
    if rules is not None or mesh is not None:
        warnings.warn(
            "passing rules=/mesh= to the step builders is deprecated; "
            "resolve a ParallelPlan and pass plan= instead "
            "(ParallelPlan.parse('dp=...').resolve(cfg, ...)). Legacy mesh "
            "strings are covered by ParallelPlan.from_legacy.",
            DeprecationWarning, stacklevel=3)
    if plan is not None:
        rules = rules if rules is not None else plan.rules
        mesh = mesh if mesh is not None else plan.mesh
        if opt_sharding_mode is None:
            opt_sharding_mode = plan.opt_shard
    return rules, mesh, opt_sharding_mode


def init_state(rng, cfg: ModelConfig, train: TrainConfig, *,
               plan: Optional[ResolvedPlan] = None, rules=None,
               mesh=None,
               opt_sharding_mode: Optional[str] = None) -> TrainState:
    """Initialize params + AdamW state. With a ``plan`` (or legacy
    ``rules``/``mesh``), every leaf is device_put onto its SO/EPSO sharding
    right after host init, so the first jitted step sees exactly the
    placement it was compiled for. (The state is still materialized on one
    device first — models that only fit sharded would jit init with these
    shardings as ``out_shardings``.)"""
    rules, mesh, opt_sharding_mode = _unpack_plan(
        plan, rules, mesh, opt_sharding_mode)
    if opt_sharding_mode is None:     # no plan, nothing requested
        opt_sharding_mode = "none"
    rules = _resolve_rules(cfg, train, rules, mesh)
    params = init_params(rng, cfg)
    opt = adamw_init(params)
    pd = jnp.dtype(train.param_dtype)
    params = jax.tree.map(lambda p: p.astype(pd), params)
    state = TrainState(params, opt)
    sh = train_state_shardings(params, rules, opt_sharding_mode)
    if sh is not None:
        state = jax.tree.map(jax.device_put, state, sh)
    return state


def make_train_step(cfg: ModelConfig, parallel: Optional[ParallelConfig],
                    train: TrainConfig, *, plan: Optional[ResolvedPlan] = None,
                    rules=None, mesh=None,
                    opt_sharding_mode: Optional[str] = None,
                    state_shardings=None):
    """Build the train step.

    The canonical call passes a resolved ``plan`` (parallel/plan.py), which
    supplies rules + mesh + optimizer-sharding mode + pipeline schedule in
    one object and scopes its KernelPlan over the step's trace (so tile
    sizes / attention impl never leak across differently-planned steps);
    ``parallel`` may then be None (derived via ``plan.parallel_config()``).

    With ``opt_sharding_mode`` set ('none'|'so'|
    'epso') the step is returned jitted with the optimizer-state shardings as
    ``out_shardings`` — XLA derives the paper's reduce-scatter (grads into
    state shards) and all-gather (updated params) from the placement
    mismatch. A caller that already holds the ``train_state_shardings`` tree
    can pass it as ``state_shardings`` to skip the abstract init re-trace.
    With ``opt_sharding_mode=None`` (default) and no plan the raw function is
    returned and the caller jits it (legacy single-device path). Whatever is
    returned carries the resolved optimizer-overlap impl
    ('off'|'ring'|'xla') as ``.opt_overlap_impl``.

    With ``parallel.pp_stages > 1`` the loss/grad computation runs through
    the jitted 1f1b/gpipe pipeline executor instead of the microbatch
    accumulation scan: the layer stack is stage-sharded over the 'pp' mesh
    axis, ``parallel.microbatches`` become the pipeline microbatches, and
    activations/cotangents hand off between stages via ppermute
    (``parallel.pipeline.pipelined_loss_and_grads``). The optimizer tail
    (cast, LR, clip, AdamW, SO/EPSO placement) is shared with the non-PP
    path."""
    rules, mesh, opt_sharding_mode = _unpack_plan(
        plan, rules, mesh, opt_sharding_mode)
    if parallel is None:
        if plan is None:
            raise ValueError("make_train_step needs a ParallelConfig or a "
                             "resolved plan")
        parallel = plan.parallel_config()
    kplan = plan.kernel if plan is not None else None
    # live expert placement (parallel/placement.py): baked into the trace as
    # an (L, E) inverse-permutation constant; identity stays None so the
    # lowering (and census baselines) are untouched without rebalancing
    pl_rows = None
    pl_obj = plan.placement if plan is not None else None
    if pl_obj is not None and not pl_obj.is_identity:
        pl_rows = jnp.asarray(pl_obj.inverse_array(), jnp.int32)
    if (parallel.moe_dispatch is not None and cfg.moe is not None
            and cfg.moe.dispatch != parallel.moe_dispatch):
        # ParallelConfig is authoritative in the step builder, so every
        # executor the step composes runs one MoE dispatch mode
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, dispatch=parallel.moe_dispatch))
    rules = _resolve_rules(cfg, train, rules, mesh)
    if mesh is None and rules is not None:
        mesh = rules.mesh
    cd = jnp.dtype(train.compute_dtype)
    pd = jnp.dtype(train.param_dtype)
    rd = jnp.dtype(train.grad_reduce_dtype)
    nmb = parallel.microbatches
    pp = parallel.pp_stages
    if pp > 1 and cfg.arch_type not in PP_ARCH_TYPES:
        raise ValueError(f"pp_stages={pp} needs arch_type in {PP_ARCH_TYPES},"
                         f" not {cfg.arch_type!r}")
    if pp > 1 and pl_rows is not None:
        raise NotImplementedError(
            "a non-identity expert placement is not threaded through the "
            "pipeline executors yet (rebalance requires pp=1)")
    if (pp > 1 and parallel.pp_impl == "shardmap" and mesh is not None
            and "pp" in getattr(mesh, "shape", {})):
        # surface the wave-balance guardrail at build time, not first call
        check_pp_microbatches(max(nmb, 1), pp)

    # overlapped SO/EPSO update (optim/overlap.py): resolved and bucket-
    # planned once at build time. 'auto' (the default) turns the bucketed
    # ring schedule on for epso on a real mesh — the mode whose eager
    # GSPMD-derived collectives regressed — and keeps 'so'/'none' eager.
    # The request follows the _unpack_plan precedence: an explicit
    # ParallelConfig.opt_overlap wins, a None defers to the plan's
    # ``overlap=`` token. Off-mesh, 'auto' degrades to 'off' but an explicit
    # ring/xla request still errors (same behavior as launch/train.py).
    ov_req = getattr(parallel, "opt_overlap", None)
    if ov_req is None and plan is not None:
        ov_req = plan.opt_overlap
    on_mesh = rules is not None and rules.mesh is not None
    ov_impl = resolve_opt_overlap(ov_req, opt_sharding_mode or "none",
                                  mesh if on_mesh else None)
    update_plan = None
    if ov_impl != "off":
        _shapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        update_plan = plan_update_buckets(_shapes, rules, opt_sharding_mode)

    # canonical expert grad-norm (optim/adamw.expert_slice_sumsq): expert
    # stacks contribute per-(L, E)-slice sums reduced in global-id order, so
    # the clip scale — the one scalar a rebalance could otherwise perturb
    # through shard-partial reassociation — is placement-invariant. Always
    # on for MoE configs so identity and placed traces share the association.
    expert_norm = None
    if cfg.moe is not None:
        _shapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        _mask = expert_leaf_mask(_shapes, cfg.num_layers,
                                 cfg.moe.num_experts)
        if any(_mask):
            expert_norm = (_mask, pl_rows)

    def loss_for(params, mb):
        return loss_fn(params, mb, cfg, rules=rules, mesh=mesh,
                       sac=parallel.remat_policy, compute_dtype=cd,
                       placement=pl_rows)

    def split_mb(batch, n):
        """(B, ...) -> (n, B/n, ...) microbatch view — shared by the PP and
        acc_step paths so their splits can never diverge."""
        return jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

    def pp_uses_shardmap():
        """The per-stage executor needs real stage shards: a mesh with a
        'pp' axis. Off-mesh (the single-device PP simulation) falls back to
        the masked executor, which bit-matches the non-PP step."""
        return (parallel.pp_impl == "shardmap" and mesh is not None
                and "pp" in getattr(mesh, "shape", {}))

    def pp_loss_and_grads(params, batch):
        """Pipelined loss+grads in 1f1b/gpipe schedule order. Both executors
        share the same model pieces (embed_tokens / pipeline_stage_forward /
        lm_head_ce), tick tables and grad contract:

        * 'masked' — single-program SPMD; bit-equal math to running the
          stage slices sequentially per microbatch and summing grads in
          microbatch order (the acc_step contract), at the cost of every
          stage computing the masked embed/head+CE each tick.
        * 'shardmap' (default on a 'pp' mesh) — shard_map-per-stage: only
          stage 0 embeds and only the last stage runs the vocab-sized
          head+CE; loss is bit-equal to 'masked', grads to ~1 ulp."""
        n_mb = max(nmb, 1)
        mbs = split_mb(batch, n_mb)
        io_params = {k: v for k, v in params.items() if k != "layers"}
        stage_params = stack_stages(params["layers"], pp, name=cfg.name)

        def embed_fn(io, mb):
            return embed_tokens(io, mb["tokens"], cfg, compute_dtype=cd)

        def block_fn(lp, h, mb):
            # NOTE: PP stages run the MoE dense path (c_align=1), not the
            # non-PP EP shard_map variant — GSPMD still shards the expert
            # compute via the param placement. Under dispatch='capacity'
            # the pool geometry matches the single-device reference but may
            # differ from an on-mesh non-PP step (c_align=dp) at shapes
            # that overflow; dispatch='dropless' is geometry-independent,
            # which closes that parity gap.
            h, aux, z, stats = pipeline_stage_forward(
                lp, h, cfg, sac=parallel.remat_policy)
            scal = {"aux": aux, "z": z}
            if cfg.is_moe:
                scal["counts"] = stats.counts
                scal["drops"] = stats.drops
            return h, scal

        def head_fn(io, h, mb):
            return lm_head_ce(io, h, mb["labels"], cfg)

        ca = cfg.moe.router_aux_coef if cfg.is_moe else 0.0
        cz = cfg.moe.router_z_coef if cfg.is_moe else 0.0
        nl = max(cfg.num_layers, 1)
        cots = {"ce": (jnp.arange(pp) == pp - 1).astype(jnp.float32),
                "aux": jnp.full((pp,), ca / nl, jnp.float32),
                "z": jnp.full((pp,), cz / nl, jnp.float32)}
        if cfg.is_moe:
            # telemetry channels: zero cotangents (counts/drops are derived
            # from integer routing decisions — no gradient flows through)
            cots["counts"] = jnp.zeros((pp, cfg.moe.num_experts), jnp.float32)
            cots["drops"] = jnp.zeros((pp,), jnp.float32)
        mb_b = batch["tokens"].shape[0] // n_mb
        seq = batch["tokens"].shape[1]
        baxes = tuple(rules.batch_axes) if rules is not None else ()
        if pp_uses_shardmap():
            ssum, g_io, g_stage = pipelined_loss_and_grads_per_stage(
                embed_fn, block_fn, head_fn, io_params, stage_params, mbs,
                cots, act_shape=(mb_b, seq, cfg.d_model), act_dtype=cd,
                schedule=parallel.pp_schedule, mesh=mesh, batch_axes=baxes)
        else:
            def stage_fn(io, lp, x, mb, sid):
                emb = embed_fn(io, mb)
                h = jnp.where(sid == 0, emb, x)      # stage 0 ingests tokens
                h, scal = block_fn(lp, h, mb)
                ce = head_fn(io, h, mb)              # masked off-last-stage
                return h, {"ce": ce, **scal}

            ssum, g_io, g_stage = pipelined_loss_and_grads(
                stage_fn, io_params, stage_params, mbs, cots,
                act_shape=(mb_b, seq, cfg.d_model), act_dtype=cd,
                schedule=parallel.pp_schedule, mesh=mesh, batch_axes=baxes)
        grads = dict(g_io)
        grads["layers"] = jax.tree.map(lambda g, p: g.reshape(p.shape),
                                       g_stage, params["layers"])
        grads = jax.tree.map(lambda g: g / n_mb, grads)
        ce = ssum["ce"][pp - 1] / n_mb
        aux = ssum["aux"].sum() / n_mb
        z = ssum["z"].sum() / n_mb
        loss = ce + (ca * aux + cz * z) / nl
        metrics = {"ce": ce}
        if cfg.is_moe:
            # sum over stages = sum over all layers and microbatches; the
            # per-layer mean makes counts sum to the whole-step T*K
            counts = ssum["counts"].sum(axis=0) / nl
            metrics["moe_counts"] = counts
            metrics["moe_load"] = counts / jnp.maximum(counts.sum(), 1.0)
            metrics["moe_drops"] = ssum["drops"].sum()
        return loss, metrics, grads

    def _train_step(state: TrainState, batch: dict):
        params = state.params

        if pp > 1:
            loss, metrics, grads = pp_loss_and_grads(params, batch)
        elif nmb > 1:
            mbs = split_mb(batch, nmb)
            m0 = {"ce": jnp.zeros(())}
            if cfg.is_moe:
                m0["moe_counts"] = jnp.zeros((cfg.moe.num_experts,),
                                             jnp.float32)
                m0["moe_drops"] = jnp.zeros((), jnp.float32)

            def acc_step(carry, mb):
                gacc, lacc, macc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_for, has_aux=True)(params, mb)
                gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                    gacc, grads)
                macc = {k: macc[k] + metrics[k] for k in macc}
                return (gacc, lacc + loss, macc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss, macc), _ = jax.lax.scan(
                acc_step, (g0, jnp.zeros(()), m0), mbs)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            loss = loss / nmb
            metrics = {"ce": macc["ce"] / nmb}
            if cfg.is_moe:
                # counts/drops are totals, not means: summed over
                # microbatches they cover the whole global batch
                counts = macc["moe_counts"]
                metrics["moe_counts"] = counts
                metrics["moe_load"] = counts / jnp.maximum(counts.sum(), 1.0)
                metrics["moe_drops"] = macc["moe_drops"]
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)

        # paper: bf16 gradient reduction (cast before the DP reduction that
        # XLA derives from the state shardings), fp32 update
        grads = jax.tree.map(lambda g: g.astype(rd).astype(jnp.float32),
                             grads)

        lr = warmup_cosine(state.opt.step, lr_peak=train.lr_peak,
                           lr_min=train.lr_min,
                           warmup_steps=train.warmup_steps,
                           total_steps=train.total_steps)
        clip_on = None
        if train.clip_after_warmup_only:
            clip_on = state.opt.step >= train.warmup_steps
        if ov_impl != "off":
            new_params, new_opt, om = overlapped_adamw_update(
                grads, state.opt, rules=rules, mode=opt_sharding_mode,
                impl=ov_impl, update_plan=update_plan, lr=lr,
                beta1=train.beta1, beta2=train.beta2, eps=train.eps,
                weight_decay=train.weight_decay, grad_clip=train.grad_clip,
                clip_enabled=clip_on, param_dtype=pd,
                expert_norm=expert_norm)
        else:
            new_params, new_opt, om = adamw_update(
                grads, state.opt, lr=lr, beta1=train.beta1,
                beta2=train.beta2, eps=train.eps,
                weight_decay=train.weight_decay, grad_clip=train.grad_clip,
                clip_enabled=clip_on, param_dtype=pd,
                expert_norm=expert_norm)
        out_metrics = {"loss": loss, "lr": lr, **metrics, **om}
        return TrainState(new_params, new_opt), out_metrics

    def train_step(state: TrainState, batch: dict):
        # the body runs at trace time, so scoping the plan's kernel config
        # here pins tile sizes / attention impl for this step's lowering
        with use_kernel_plan(kplan):
            return _train_step(state, batch)

    if opt_sharding_mode is None:
        fn = train_step
    elif rules is None or rules.mesh is None:
        fn = jax.jit(train_step)
    else:
        ssh = state_shardings
        if ssh is None:
            shapes = jax.eval_shape(
                lambda: init_params(jax.random.PRNGKey(0), cfg))
            ssh = train_state_shardings(shapes, rules, opt_sharding_mode)
        # metrics subtree: None = unconstrained (scalars; XLA replicates)
        fn = jax.jit(train_step, out_shardings=(ssh, None))
    # the resolved overlap impl, for callers that record/assert what the
    # built step actually runs (bench_epso.py, test_opt_overlap.py)
    fn.opt_overlap_impl = ov_impl
    return fn


def make_prefill_step(cfg: ModelConfig, *, plan: Optional[ResolvedPlan] = None,
                      rules=None, mesh=None,
                      compute_dtype=jnp.bfloat16, into_cache: bool = False):
    """``into_cache=False``: the prefill_32k lowering — forward over the
    batch, last-position logits. ``into_cache=True``: the serve engine's
    admission lowering — ``prefill_step(params, tokens, cache, slots,
    lengths)`` writes the prompts' K/V into the given cache slots and
    returns (last_logits, new_cache); see models.prefill_with_cache."""
    rules, mesh, _ = _unpack_plan(plan, rules, mesh, "none")
    kplan = plan.kernel if plan is not None else None
    if into_cache:
        from repro.serve.engine import dropless_cfg
        scfg = dropless_cfg(cfg)   # serving must be batching-transparent

        def prefill_step(params, tokens, cache, slots, lengths):
            with use_kernel_plan(kplan):
                return prefill_with_cache(params, tokens, cache, slots,
                                          lengths, scfg, rules=rules,
                                          mesh=mesh,
                                          compute_dtype=compute_dtype)

        return prefill_step

    def prefill_step(params, batch):
        with use_kernel_plan(kplan):
            logits, _ = forward(params, batch, cfg, rules=rules, mesh=mesh,
                                sac="", compute_dtype=compute_dtype)
            return logits[:, -1]

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, plan: Optional[ResolvedPlan] = None,
                    rules=None, compute_dtype=jnp.bfloat16,
                    sample: bool = False):
    """``index`` may be a scalar (lockstep batch, the decode_32k shape) or a
    (B,) vector of per-slot positions (continuous batching). With
    ``sample=True`` returns the serve engine's full decode lowering —
    ``(params, tokens, cache, positions, seeds, temperature, top_k, top_p)
    -> (next_tokens, new_cache)`` — built by serve.make_decode_fn."""
    rules, _, _ = _unpack_plan(plan, rules, None, "none")
    kplan = plan.kernel if plan is not None else None
    if sample:
        from repro.serve.engine import make_decode_fn
        return make_decode_fn(cfg, rules=rules, compute_dtype=compute_dtype,
                              kernel_plan=kplan)

    def serve_step(params, tokens, cache, index):
        with use_kernel_plan(kplan):
            return decode_step(params, tokens, cache, index, cfg, rules=rules,
                               compute_dtype=compute_dtype)

    return serve_step
