"""Training / serving steps.

``train_step`` implements the paper's recipe (§2.1): bf16 fwd/bwd on bf16
params, bf16 gradient reduction, fp32 master weights + AdamW states (held in
the optimizer state, sharded per SO/EPSO), warmup+cosine LR, global-norm
clipping enabled only after warmup, gradient accumulation over microbatches
via ``lax.scan``, SAC remat policies.

``serve_step`` is single-token decode against a KV/SSM cache (the lowering
target for decode_32k / long_500k) — with ``sample=True`` it becomes the
serve engine's decode lowering (per-slot positions + per-request sampling;
repro/serve/engine.py). ``prefill_step`` is the forward pass for prefill_32k;
with ``into_cache=True`` it writes prompt K/V straight into cache slots (the
engine's admission path).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.models import (init_params, loss_fn, forward, init_cache,
                          decode_step, prefill_with_cache)
from repro.optim import adamw_init, adamw_update, warmup_cosine, AdamWState


class TrainState(NamedTuple):
    params: dict          # compute-precision params (bf16 in production)
    opt: AdamWState       # fp32 master + moments


def init_state(rng, cfg: ModelConfig, train: TrainConfig) -> TrainState:
    params = init_params(rng, cfg)
    opt = adamw_init(params)
    pd = jnp.dtype(train.param_dtype)
    params = jax.tree.map(lambda p: p.astype(pd), params)
    return TrainState(params, opt)


def make_train_step(cfg: ModelConfig, parallel: ParallelConfig,
                    train: TrainConfig, *, rules=None, mesh=None):
    cd = jnp.dtype(train.compute_dtype)
    pd = jnp.dtype(train.param_dtype)
    rd = jnp.dtype(train.grad_reduce_dtype)
    nmb = parallel.microbatches

    def loss_for(params, mb):
        return loss_fn(params, mb, cfg, rules=rules, mesh=mesh,
                       sac=parallel.remat_policy, compute_dtype=cd)

    def train_step(state: TrainState, batch: dict):
        params = state.params

        if nmb > 1:
            def split(x):
                return x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                gacc, lacc, macc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_for, has_aux=True)(params, mb)
                gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                    gacc, grads)
                return (gacc, lacc + loss, macc + metrics["ce"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss, ce), _ = jax.lax.scan(
                acc_step, (g0, jnp.zeros(()), jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            loss, ce = loss / nmb, ce / nmb
            metrics = {"ce": ce}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)

        # paper: bf16 gradient reduction (cast before the DP reduction that
        # XLA derives from the state shardings), fp32 update
        grads = jax.tree.map(lambda g: g.astype(rd).astype(jnp.float32),
                             grads)

        lr = warmup_cosine(state.opt.step, lr_peak=train.lr_peak,
                           lr_min=train.lr_min,
                           warmup_steps=train.warmup_steps,
                           total_steps=train.total_steps)
        clip_on = None
        if train.clip_after_warmup_only:
            clip_on = state.opt.step >= train.warmup_steps
        new_params, new_opt, om = adamw_update(
            grads, state.opt, lr=lr, beta1=train.beta1, beta2=train.beta2,
            eps=train.eps, weight_decay=train.weight_decay,
            grad_clip=train.grad_clip, clip_enabled=clip_on, param_dtype=pd)
        out_metrics = {"loss": loss, "lr": lr, **metrics, **om}
        return TrainState(new_params, new_opt), out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, *, rules=None, mesh=None,
                      compute_dtype=jnp.bfloat16, into_cache: bool = False):
    """``into_cache=False``: the prefill_32k lowering — forward over the
    batch, last-position logits. ``into_cache=True``: the serve engine's
    admission lowering — ``prefill_step(params, tokens, cache, slots,
    lengths)`` writes the prompts' K/V into the given cache slots and
    returns (last_logits, new_cache); see models.prefill_with_cache."""
    if into_cache:
        from repro.serve.engine import dropless_cfg
        scfg = dropless_cfg(cfg)   # serving must be batching-transparent

        def prefill_step(params, tokens, cache, slots, lengths):
            return prefill_with_cache(params, tokens, cache, slots, lengths,
                                      scfg, rules=rules, mesh=mesh,
                                      compute_dtype=compute_dtype)

        return prefill_step

    def prefill_step(params, batch):
        logits, _ = forward(params, batch, cfg, rules=rules, mesh=mesh,
                            sac="", compute_dtype=compute_dtype)
        return logits[:, -1]

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, rules=None,
                    compute_dtype=jnp.bfloat16, sample: bool = False):
    """``index`` may be a scalar (lockstep batch, the decode_32k shape) or a
    (B,) vector of per-slot positions (continuous batching). With
    ``sample=True`` returns the serve engine's full decode lowering —
    ``(params, tokens, cache, positions, seeds, temperature, top_k, top_p)
    -> (next_tokens, new_cache)`` — built by serve.make_decode_fn."""
    if sample:
        from repro.serve.engine import make_decode_fn
        return make_decode_fn(cfg, rules=rules, compute_dtype=compute_dtype)

    def serve_step(params, tokens, cache, index):
        return decode_step(params, tokens, cache, index, cfg, rules=rules,
                           compute_dtype=compute_dtype)

    return serve_step
