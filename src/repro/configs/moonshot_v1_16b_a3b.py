"""moonshot-v1-16b-a3b: fine-grained MoE (Moonlight-16B-A3B family).

48L d_model=2048 16H (kv=16) d_ff=1408 (per-expert) vocab=163840,
MoE 64 experts top-6. [hf:moonshotai/Moonlight-16B-A3B]. DeepSeek-V3-style
fine-grained experts with 2 shared experts; SwiGLU, RMSNorm, RoPE.
This is exactly the many-small-experts regime FastSparseMoE targets.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", arch_type="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=0, vocab_size=163840,
    moe=MoEConfig(num_experts=64, experts_per_token=6, d_ff_expert=1408,
                  num_shared_experts=2, moe_impl="fsmoe"),
    citation="hf:moonshotai/Moonlight-16B-A3B")
