"""phi-3-vision-4.2b [vlm]: phi3-mini decoder + CLIP vision tower (stubbed).

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.
[hf:microsoft/Phi-3-vision-128k-instruct]. The CLIP ViT + projector is a
STUB per spec: input_specs() supplies precomputed patch embeddings
(B, patches, d_model) spliced before the text tokens. The language decoder
(SwiGLU, RMSNorm, RoPE) is implemented fully. long_500k skipped (full attn).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", arch_type="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    num_prefix_embeds=576,   # 24x24 patch grid from the stub vision tower
    citation="hf:microsoft/Phi-3-vision-128k-instruct")
