"""falcon-mamba-7b [ssm]: attention-free Mamba-1 architecture.

64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16.
[arXiv:2410.05355 — Falcon Mamba]. Pure Mamba-1 blocks (d_inner=2*d_model,
dt_rank=d_model/16, depthwise conv4). EP/FSMOE inapplicable (no experts);
long_500k decode runs with O(1) recurrent state.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", arch_type="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=65024,
    ssm=SSMConfig(variant="mamba1", d_state=16, d_conv=4, expand=2),
    citation="arXiv:2410.05355")
