"""Config registry: ``get_config(arch_id)`` / ``ARCH_REGISTRY``.

Assigned architectures (public-literature pool) + the paper's own Mula family.
"""
from .base import (ModelConfig, MoEConfig, SSMConfig, ParallelConfig,
                   TrainConfig, InputShape, INPUT_SHAPES, reduced)
from . import (zamba2_7b, starcoder2_3b, falcon_mamba_7b, deepseek_7b,
               seamless_m4t_medium, dbrx_132b, llama3_405b,
               phi_3_vision_4_2b, mixtral_8x7b, moonshot_v1_16b_a3b)
from . import mula

ARCH_REGISTRY = {
    # assigned pool
    "zamba2-7b": zamba2_7b.CONFIG,
    "starcoder2-3b": starcoder2_3b.CONFIG,
    "falcon-mamba-7b": falcon_mamba_7b.CONFIG,
    "deepseek-7b": deepseek_7b.CONFIG,
    "seamless-m4t-medium": seamless_m4t_medium.CONFIG,
    "dbrx-132b": dbrx_132b.CONFIG,
    "llama3-405b": llama3_405b.CONFIG,
    "phi-3-vision-4.2b": phi_3_vision_4_2b.CONFIG,
    "mixtral-8x7b": mixtral_8x7b.CONFIG,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b.CONFIG,
    # paper Table 1
    "mula-1b": mula.MULA_1B,
    "mula-7b-a1b": mula.MULA_7B_A1B,
    "mula-20b-a2b": mula.MULA_20B_A2B,
    "mula-100b-a7b": mula.MULA_100B_A7B,
    "mula-220b-a10b": mula.MULA_220B_A10B,
}

ASSIGNED_ARCHS = [
    "zamba2-7b", "starcoder2-3b", "falcon-mamba-7b", "deepseek-7b",
    "seamless-m4t-medium", "dbrx-132b", "llama3-405b", "phi-3-vision-4.2b",
    "mixtral-8x7b", "moonshot-v1-16b-a3b",
]


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCH_REGISTRY)}")
    return ARCH_REGISTRY[arch_id]


__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "ParallelConfig",
           "TrainConfig", "InputShape", "INPUT_SHAPES", "reduced",
           "ARCH_REGISTRY", "ASSIGNED_ARCHS", "get_config"]
