"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64.
[arXiv:2411.15242 — Zamba2 technical report]

Structure (adapted): 81 Mamba2 layers; a single *shared-weight*
attention+MLP block is applied every 6 layers (Zamba2 interleaves shared
transformer blocks among Mamba2 blocks; we model the shared-weight pattern
with period 6 ≈ 13 applications over 81 layers).
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", arch_type="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    ssm=SSMConfig(variant="mamba2", d_state=64, d_conv=4, expand=2,
                  headdim=64, chunk=256),
    shared_attn_every=6,
    citation="arXiv:2411.15242")
