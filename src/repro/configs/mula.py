"""Paper Table 1: Mula model family (OLMo / OLMoE architecture).

Mula models use: RMSNorm... the OLMo/OLMoE family uses non-parametric
LayerNorm + SwiGLU + RoPE; we follow OLMoE (rmsnorm variant via QK-norm is
omitted) with SwiGLU MLPs/experts. head_size 128 throughout (paper Table 1).
"""
from .base import ModelConfig, MoEConfig

_CITE = "Vooturi et al., Scalable Pretraining of Large MoE LMs on Aurora, 2026 (Table 1)"


def _moe(num_experts: int, d_ff_expert: int) -> MoEConfig:
    return MoEConfig(
        num_experts=num_experts, experts_per_token=8, d_ff_expert=d_ff_expert,
        router_aux_coef=0.01, router_z_coef=0.001, moe_impl="fsmoe")


MULA_1B = ModelConfig(
    name="mula-1b", arch_type="dense",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=8192, vocab_size=50304, citation=_CITE)

MULA_7B_A1B = ModelConfig(
    name="mula-7b-a1b", arch_type="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=0, vocab_size=50304, moe=_moe(64, 1024), citation=_CITE)

MULA_20B_A2B = ModelConfig(
    name="mula-20b-a2b", arch_type="moe",
    num_layers=32, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=0, vocab_size=50304, moe=_moe(96, 1024), citation=_CITE)

MULA_100B_A7B = ModelConfig(
    name="mula-100b-a7b", arch_type="moe",
    num_layers=48, d_model=3072, num_heads=24, num_kv_heads=24, head_dim=128,
    d_ff=0, vocab_size=50304, moe=_moe(144, 1536), citation=_CITE)

MULA_220B_A10B = ModelConfig(
    name="mula-220b-a10b", arch_type="moe",
    num_layers=64, d_model=3072, num_heads=24, num_kv_heads=24, head_dim=128,
    d_ff=0, vocab_size=50304, moe=_moe(240, 1536), citation=_CITE)
