"""seamless-m4t-medium [audio]: encoder-decoder multimodal backbone.

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
[arXiv:2308.11596 — SeamlessM4T]. We implement the transformer backbone
(12 encoder + 12 decoder layers, cross-attention, GELU, LayerNorm). The
speech frontend (mel-spectrogram + conformer feature extractor) is a STUB
per spec: input_specs() supplies precomputed frame embeddings (B, frames,
d_model) to the encoder.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", arch_type="audio",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206,
    is_encoder_decoder=True, num_encoder_layers=12,
    num_prefix_embeds=1,  # encoder consumes stub frame embeddings
    mlp_activation="gelu", norm="layernorm",
    citation="arXiv:2308.11596")
