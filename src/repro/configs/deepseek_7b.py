"""deepseek-7b [dense]: llama-architecture decoder.

30L d_model=4096 32H (kv=32, i.e. MHA) d_ff=11008 vocab=102400.
[arXiv:2401.02954 — DeepSeek LLM]. SwiGLU + RMSNorm + RoPE.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", arch_type="dense",
    num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=102400,
    citation="arXiv:2401.02954")
