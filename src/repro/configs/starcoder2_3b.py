"""starcoder2-3b [dense]: GQA (kv=2), RoPE, sliding-window 4096.

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
[arXiv:2402.19173 — StarCoder2]. StarCoder2 uses GELU MLP + LayerNorm and
sliding-window attention (window 4096), which lets it run long_500k decode.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", arch_type="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2, head_dim=128,
    d_ff=12288, vocab_size=49152,
    sliding_window=4096, mlp_activation="gelu", norm="layernorm",
    rope_theta=1e5,
    citation="arXiv:2402.19173")
