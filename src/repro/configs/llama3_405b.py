"""llama3-405b [dense]: 126L GQA, 128k vocab.

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
[arXiv:2407.21783 — The Llama 3 Herd of Models]. SwiGLU + RMSNorm + RoPE
(theta 5e5). 405B params require FSDP-style two-axis parameter sharding
(see DESIGN §6/§7); long_500k skipped (full attention).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", arch_type="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8, head_dim=128,
    d_ff=53248, vocab_size=128256,
    rope_theta=5e5,
    citation="arXiv:2407.21783")
