"""Configuration system for Optimus-JAX.

ModelConfig captures every architecture family the framework supports
(dense / MoE / SSM / hybrid / enc-dec audio / VLM). ParallelConfig captures
the distribution strategy; TrainConfig the optimization recipe (paper §2.1).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    experts_per_token: int = 0          # top-k
    d_ff_expert: int = 0                # per-expert intermediate size
    num_shared_experts: int = 0         # always-on experts (moonlight-style)
    capacity_factor: float = 1.25       # static-capacity adaptation (DESIGN §3)
    router_aux_coef: float = 0.01       # load-balance aux loss (OLMoE recipe)
    router_z_coef: float = 0.001        # router z-loss
    forced_uniform_routing: bool = False  # FUR (paper §2.3)
    # 'naive' | 'dense_capacity' | 'fsmoe'  (DESIGN §4)
    moe_impl: str = "dense_capacity"
    # 'xla' | 'pallas' — backend for fsmoe stages 2/4/5
    kernel_backend: str = "xla"
    # beyond-paper (EXPERIMENTS §Perf): explicit shard_map ETP path when the
    # model axis plays expert-tensor-parallel (E < axis size)
    etp_shard_map: bool = False
    # Stage 1 variant: 'allgather' (paper) | 'a2a' (beyond-paper, capacity-
    # bounded all-to-all dispatch)
    stage1: str = "allgather"
    # dispatch mode: 'capacity' sizes the slot pool by capacity_factor and
    # drops over-capacity tokens; 'dropless' sizes it for the worst-case
    # routing so every (token, expert) pair is computed (no drops, exact
    # naive-equal math independent of pool geometry / c_align).
    dispatch: str = "capacity"

    def __post_init__(self):
        if self.dispatch not in ("capacity", "dropless"):
            raise ValueError(f"MoEConfig.dispatch must be 'capacity' or "
                             f"'dropless', got {self.dispatch!r}")


@dataclass(frozen=True)
class SSMConfig:
    variant: str = "mamba1"             # 'mamba1' | 'mamba2'
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                     # d_inner = expand * d_model
    headdim: int = 64                   # mamba2 head dim
    chunk: int = 64                     # mamba2 SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                           # dense-MLP intermediate (0 = no MLP)
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // num_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # attention
    rope_theta: float = 10000.0
    sliding_window: int = 0             # 0 = full attention
    # hybrid (zamba2-style): a *shared-weight* attention(+MLP) block applied
    # every `shared_attn_every` layers.
    shared_attn_every: int = 0
    # enc-dec
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    # modality frontend stub: inputs include precomputed prefix embeddings
    # (ViT patches / audio frames) of shape (B, num_prefix_embeds, d_model).
    num_prefix_embeds: int = 0
    mlp_activation: str = "swiglu"      # swiglu | gelu
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    tie_embeddings: bool = False
    citation: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived quantities -------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.moe is not None and self.moe.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """sub-quadratic decode: SSM/hybrid state or sliding-window KV."""
        return self.arch_type in ("ssm", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic total parameter count (embedding + blocks + head)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                      # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # lm head
        n += self._block_params()
        n += d                                        # final norm
        return n

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        return q + kv + o

    def _mlp_params(self, d_ff: int) -> int:
        d = self.d_model
        if self.mlp_activation == "swiglu":
            return 3 * d * d_ff
        return 2 * d * d_ff

    def _ssm_params(self) -> int:
        assert self.ssm is not None
        d = self.d_model
        di = self.ssm.expand * d
        ds = self.ssm.d_state
        if self.ssm.variant == "mamba1":
            dt_rank = max(1, d // 16)
            n = d * 2 * di                           # in_proj
            n += di * self.ssm.d_conv                # conv1d (depthwise)
            n += di * (dt_rank + 2 * ds)             # x_proj
            n += dt_rank * di + di                   # dt_proj
            n += di * ds + di                        # A_log, D
            n += di * d                              # out_proj
            return n
        else:  # mamba2
            nheads = di // self.ssm.headdim
            conv_dim = di + 2 * ds
            n = d * (2 * di + 2 * ds + nheads)       # in_proj (z,x,B,C,dt)
            n += conv_dim * self.ssm.d_conv          # conv1d
            n += nheads * 3                          # A_log, D, dt_bias
            n += di                                  # pre-out norm
            n += di * d                              # out_proj
            return n

    def _block_params(self) -> int:
        d = self.d_model
        per_norm = d
        total = 0
        if self.arch_type == "ssm":
            total += self.num_layers * (self._ssm_params() + per_norm)
        elif self.arch_type == "hybrid":
            total += self.num_layers * (self._ssm_params() + per_norm)
            # one shared attention+MLP block (weights shared across uses)
            total += self._attn_params() + self._mlp_params(self.d_ff) + 2 * per_norm
        else:
            per_block = self._attn_params() + 2 * per_norm
            if self.is_moe:
                m = self.moe
                per_block += d * m.num_experts       # router
                per_block += m.num_experts * 3 * d * m.d_ff_expert
                per_block += m.num_shared_experts * 3 * d * m.d_ff_expert
            else:
                per_block += self._mlp_params(self.d_ff)
            total += self.num_layers * per_block
            if self.is_encoder_decoder:
                enc_block = self._attn_params() + self._mlp_params(self.d_ff) + 2 * per_norm
                total += self.num_encoder_layers * enc_block
                # decoder cross-attention
                total += self.num_layers * (self._attn_params() + per_norm)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        m = self.moe
        inactive = self.num_layers * 3 * self.d_model * m.d_ff_expert * (
            m.num_experts - m.experts_per_token)
        return self.param_count() - inactive


@dataclass(frozen=True)
class ParallelConfig:
    """How the model maps onto the ('data','model') / ('pod','data','model') mesh."""
    # role of the 'model' axis for this arch: 'tp' | 'ep' | 'etp' (expert-TP)
    model_axis_role: str = "tp"
    # shard params over the data axis too (ZeRO-3/FSDP style) — for 405B-class
    fsdp_params: bool = False
    # optimizer state sharding: 'none' | 'so' (DP only) | 'epso' (DP x MP)
    optimizer_sharding: str = "epso"
    # overlapped optimizer collectives (optim/overlap.py): None/'auto' turns
    # the bucketed ring update on for epso on a real mesh; 'ring'/'xla' force
    # an impl; 'off' keeps the eager GSPMD-derived tail.
    opt_overlap: Optional[str] = None   # None|'auto'|'off'|'ring'|'xla'
    # selective activation checkpointing modules (paper §1 SAC)
    remat_policy: str = "block"     # none|norm|attn|moe|block(=full block inputs)
    # gradient accumulation microbatches inside train_step
    microbatches: int = 1
    # pipeline parallelism (paper-faithful Mula-100B/220B path): stages map
    # onto the 'pp' mesh axis; microbatches become pipeline microbatches
    pp_stages: int = 1
    pp_schedule: str = "1f1b"       # gpipe | 1f1b
    # executor: 'shardmap' = per-stage programs over the 'pp' axis (only
    # stage 0 embeds, only the last stage runs head+CE); 'masked' = legacy
    # single-program SPMD where every stage pays the masked embed/head cost.
    # 'shardmap' needs a meshed 'pp' axis; off-mesh runs fall back to
    # 'masked' (the single-device PP simulation).
    pp_impl: str = "shardmap"       # shardmap | masked
    # MoE dispatch override: None defers to MoEConfig.dispatch; 'capacity' /
    # 'dropless' force that path in the step builder so every executor the
    # step composes (plain, microbatched, both PP executors) runs one MoE
    # dispatch mode.
    moe_dispatch: Optional[str] = None

    def __post_init__(self):
        if self.pp_schedule not in ("gpipe", "1f1b"):
            raise ValueError(f"pp_schedule must be 'gpipe' or '1f1b', "
                             f"got {self.pp_schedule!r}")
        if self.pp_impl not in ("shardmap", "masked"):
            raise ValueError(f"pp_impl must be 'shardmap' or 'masked', "
                             f"got {self.pp_impl!r}")
        if self.moe_dispatch not in (None, "capacity", "dropless"):
            raise ValueError(f"moe_dispatch must be None, 'capacity' or "
                             f"'dropless', got {self.moe_dispatch!r}")
        if self.opt_overlap not in (None, "auto", "off", "ring", "xla"):
            raise ValueError(f"opt_overlap must be None, 'auto', 'off', "
                             f"'ring' or 'xla', got {self.opt_overlap!r}")
        if self.pp_stages < 1:
            raise ValueError(f"pp_stages must be >= 1, got {self.pp_stages}")
        if self.microbatches < 1:
            raise ValueError(
                f"microbatches must be >= 1, got {self.microbatches}")


@dataclass(frozen=True)
class TrainConfig:
    """Paper §2.1 recipe."""
    seq_len: int = 2048
    global_batch: int = 3072
    lr_peak: float = 4e-4
    lr_min: float = 4e-5
    warmup_steps: int = 2500
    total_steps: int = 630_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.99
    eps: float = 1e-8
    grad_clip: float = 1.0
    clip_after_warmup_only: bool = True   # paper: clip only after warmup
    grad_reduce_dtype: str = "bfloat16"   # paper: bf16 gradient reduction
    param_dtype: str = "float32"          # fp32 master weights
    compute_dtype: str = "bfloat16"       # bf16 fwd/bwd
    seed: int = 0


# ---- input shapes (assigned) -------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 256,
            max_experts: int = 4, vocab: int = 512) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests."""
    num_heads = max(2, min(4, cfg.num_heads))
    ratio = max(1, cfg.num_heads // max(1, cfg.num_kv_heads))
    num_kv = max(1, num_heads // min(ratio, num_heads))
    moe = None
    if cfg.moe is not None:
        ne = min(max_experts, cfg.moe.num_experts)
        moe = dataclasses.replace(
            cfg.moe, num_experts=ne,
            experts_per_token=min(cfg.moe.experts_per_token, max(1, ne // 2)),
            d_ff_expert=min(cfg.moe.d_ff_expert, d_model // 2) if cfg.moe.d_ff_expert else 0,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, d_state=min(cfg.ssm.d_state, 16),
                                  headdim=32, chunk=16)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        num_encoder_layers=min(cfg.num_encoder_layers, layers),
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=d_model // num_heads,
        d_ff=min(cfg.d_ff, d_model * 2) if cfg.d_ff else 0,
        vocab_size=vocab,
        moe=moe,
        ssm=ssm,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        shared_attn_every=2 if cfg.shared_attn_every else 0,
        num_prefix_embeds=min(cfg.num_prefix_embeds, 8),
    )
