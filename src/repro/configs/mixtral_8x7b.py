"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 (per-expert) vocab=32000.
[arXiv:2401.04088 — Mixtral of Experts]. SWA window 4096 => long_500k decode
runs with a ring-buffer KV cache. E=8 < 16-way model axis, so experts are
sharded with expert-tensor-parallelism (d_ff split across the model axis) —
see DESIGN §6 Arch-applicability.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", arch_type="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=0, vocab_size=32000,
    moe=MoEConfig(num_experts=8, experts_per_token=2, d_ff_expert=14336,
                  moe_impl="fsmoe"),
    sliding_window=4096,
    citation="arXiv:2401.04088")
