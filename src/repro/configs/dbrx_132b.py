"""dbrx-132b [moe]: 16 experts, top-4, fine-grained.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 (per-expert) vocab=100352.
[hf:databricks/dbrx-base]. SwiGLU experts, GQA, RoPE. EP degree 16 on the
production mesh (1 expert per model-axis device).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", arch_type="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=0, vocab_size=100352,
    moe=MoEConfig(num_experts=16, experts_per_token=4, d_ff_expert=10752,
                  moe_impl="fsmoe"),
    rope_theta=5e5,
    citation="hf:databricks/dbrx-base")
