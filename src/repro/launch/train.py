"""End-to-end training launcher.

Wires together the full substrate: data pipeline (tokenize/shuffle/shard +
mmap loader), model zoo, FSMOE, AdamW with SO/EPSO state sharding jitted as
``out_shardings``, SAC, dual + model-only checkpointing with reshard-on-
restore, and the paper §4 failure-handling loop (NaN monitor + buffer-node
ClusterManager) as the main loop. Reduced-scale runs reproduce the paper's
Figure 1 training curves (see examples/train_mula.py).

Usage (single device):
  PYTHONPATH=src python -m repro.launch.train --arch mula-7b-a1b --scale smoke \
      --steps 100 --batch 8 --seq 128 --out runs/mula7b

Usage (simulated 8-device mesh, EP-aware sharded optimizer, survives an
injected hard node failure at step 12 via buffer-node swap + restore):
  PYTHONPATH=src python -m repro.launch.train --arch mula-7b-a1b --scale smoke \
      --mesh 4,2 --opt-shard epso --steps 20 --inject-hard-at 12

Usage (declarative plan: 2-way DP x 2 pipeline stages x 2-way EP, jitted
1f1b schedule composed with EPSO + fault tolerance):
  PYTHONPATH=src python -m repro.launch.train --arch mula-7b-a1b --scale smoke \
      --parallel dp=2,pp=2,ep=2 --opt-shard epso --steps 20

Usage (expert-TP: EP and TP as *distinct* axes — each expert's d_ff sharded
2-way on top of 2-way expert parallelism; inexpressible with --mesh):
  PYTHONPATH=src python -m repro.launch.train --arch mula-7b-a1b --scale smoke \
      --parallel dp=2,ep=2,tp=2 --steps 10

The legacy ``--mesh dp[,pp][,model]`` spec still works: it is translated to
a ParallelPlan via ``ParallelPlan.from_legacy`` (the old role inference on
the 'model' axis — EP when the expert count divides it, TP otherwise).
Both paths force the plan's device product as CPU host devices through
XLA_FLAGS when the backend allows it (see launch/mesh, parallel/plan).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ParallelConfig, TrainConfig, get_config, reduced)
from repro.data import ByteTokenizer, ShardedDataLoader, preprocess_corpus
from repro.checkpoint import Checkpointer
from repro.ft import (ClusterManager, NaNMonitor, NodeFailure,
                      run_with_failure_handling)
from repro.parallel.plan import ParallelPlan
from repro.parallel.sharding import batch_sharding
from repro.train import init_state, make_train_step, train_state_shardings
from repro.models import padded_vocab


class RunResult(list):
    """History list (one dict per executed step, in step order) plus
    fault-tolerance bookkeeping from the launcher loop."""
    relaunches: int = 0
    replaced: list = ()


def synthetic_corpus(n_files: int = 4, docs_per_file: int = 64,
                     seed: int = 0):
    """Procedural text corpus: Zipf-ish word soup with structure, so the
    loss curve has signal (byte-level models learn digraph statistics)."""
    rng = np.random.default_rng(seed)
    words = ["the", "model", "expert", "router", "token", "aurora", "tile",
             "pipeline", "gradient", "optimizer", "state", "shard", "mixture",
             "attention", "scan", "chunk", "loss", "batch", "step", "node"]
    probs = 1.0 / np.arange(1, len(words) + 1)
    probs /= probs.sum()
    files = []
    for _ in range(n_files):
        docs = []
        for _ in range(docs_per_file):
            n = int(rng.integers(30, 120))
            docs.append(" ".join(rng.choice(words, size=n, p=probs)) + ".")
        files.append(docs)
    return files


def prepare_data(out_dir: str, *, context: int, seed: int = 0,
                 n_files: int = 4, docs_per_file: int = 256):
    data_dir = os.path.join(out_dir, "data")
    if not os.path.exists(os.path.join(data_dir, "meta.json")):
        preprocess_corpus(synthetic_corpus(n_files, docs_per_file, seed),
                          data_dir, context=context, seed=seed)
    return data_dir


def _env_int(name: str):
    v = os.environ.get(name)
    return int(v) if v else None


def run(arch: str, *, scale: str = "smoke", steps: int = 100, batch: int = 8,
        seq: int = 128, out: str = "runs/default", lr: float = 1e-3,
        moe_impl: str = None, fur: bool = False, ckpt_interval: int = 50,
        microbatches: int = 1, sac: str = "block", seed: int = 0,
        log_every: int = 10, d_model: int = 256, layers: int = 2,
        d_ff: int = 0, moe_dff: int = 0, mesh: str = None,
        parallel: str = None,
        opt_shard: str = None, opt_overlap: str = None,
        pp_schedule: str = None,
        pp_impl: str = None, moe_dispatch: str = None,
        kernel_tiles: str = None,
        rebalance: str = None, rebalance_force_at: int = None,
        n_buffer: int = 2,
        inject_hard_at: int = None, inject_soft_at: int = None,
        max_relaunches: int = 8) -> RunResult:
    # opt_shard/pp_schedule: None = not passed (the --parallel spec's opt=/
    # schedule= options apply); an explicit value — including the defaults
    # 'none'/'1f1b' — overrides the spec.
    if opt_shard not in (None, "none") and not (mesh or parallel):
        raise ValueError(f"--opt-shard {opt_shard} needs --parallel (or the "
                         f"legacy --mesh): optimizer-state sharding is a "
                         f"placement over mesh axes")
    if mesh and parallel:
        raise ValueError("--mesh and --parallel are mutually exclusive "
                         "(--mesh is the legacy spelling of --parallel)")
    os.makedirs(out, exist_ok=True)

    # cfg is pure python — build it before the plan resolves (the resolve
    # forces host devices, which must precede JAX backend initialization)
    cfg = get_config(arch)
    if scale == "smoke":
        cfg = reduced(cfg, layers=layers, d_model=d_model,
                      vocab=ByteTokenizer.VOCAB)
    else:
        cfg = dataclasses.replace(cfg, vocab_size=ByteTokenizer.VOCAB)
    if d_ff:
        cfg = dataclasses.replace(cfg, d_ff=d_ff)
    if cfg.moe is not None and (moe_impl or fur or moe_dff):
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, moe_impl=moe_impl or cfg.moe.moe_impl,
            forced_uniform_routing=fur,
            d_ff_expert=moe_dff or cfg.moe.d_ff_expert))

    # ---- the ParallelPlan: --parallel spec, or the legacy --mesh shim ----
    if parallel:
        pplan = ParallelPlan.parse(parallel)
        if opt_shard is not None:               # CLI flag overrides the spec
            pplan = dataclasses.replace(pplan, opt_shard=opt_shard)
        if opt_overlap is not None:
            pplan = dataclasses.replace(pplan, opt_overlap=opt_overlap)
        if pp_schedule is not None:
            pplan = dataclasses.replace(pplan, pp_schedule=pp_schedule)
        if pp_impl is not None:
            pplan = dataclasses.replace(pplan, pp_impl=pp_impl)
        if moe_dispatch is not None:
            pplan = dataclasses.replace(pplan, moe_dispatch=moe_dispatch)
    elif mesh:
        pplan = ParallelPlan.from_legacy(mesh, cfg=cfg,
                                         opt_shard=opt_shard or "none",
                                         pp_schedule=pp_schedule or "1f1b")
        if opt_overlap is not None:
            pplan = dataclasses.replace(pplan, opt_overlap=opt_overlap)
        if pp_impl is not None:
            pplan = dataclasses.replace(pplan, pp_impl=pp_impl)
        if moe_dispatch is not None:
            pplan = dataclasses.replace(pplan, moe_dispatch=moe_dispatch)
    else:
        pplan = None
    # one MoE dispatch path everywhere: fold the plan-pinned (or --moe-
    # dispatch) mode into the model config before anything resolves on it
    if pplan is not None:
        cfg = pplan.apply_to_model(cfg)
    elif moe_dispatch is not None and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, dispatch=moe_dispatch))
    if kernel_tiles is not None:
        # 'auto' resolves tiles per shape bucket from the measured tuning
        # table (kernels/autotune.py); 'TMxTKxTN' pins an explicit triple.
        # Overrides a --parallel spec's tiles= option.
        from repro.parallel.plan import _apply_tiles_token
        if pplan is None:
            pplan = ParallelPlan()
        pplan = dataclasses.replace(
            pplan, kernel=_apply_tiles_token(pplan.kernel, kernel_tiles))
    if rebalance is not None:           # CLI flag overrides the spec token
        if pplan is None:
            raise ValueError("--rebalance needs --parallel (or --mesh): "
                             "rebalancing re-places experts over the EP axis")
        pplan = dataclasses.replace(pplan, rebalance=rebalance)
    opt_shard = pplan.opt_shard if pplan is not None else (opt_shard
                                                           or "none")

    # a pp plan axis > 1 turns on the jitted 1f1b/gpipe pipeline:
    # microbatches become pipeline microbatches.
    pp_stages = pplan.pp if pplan is not None else 1
    if microbatches == 1 and pplan is not None and pplan.microbatches > 1:
        microbatches = pplan.microbatches       # spec-supplied mb=
    if pp_stages > 1 and microbatches == 1:
        # only the untouched default is bumped; an explicit --microbatches
        # is honored as-is (any value >= 1 pipelines, just with more bubble).
        # The default must divide the batch — prefer 2*pp, fall back to pp.
        for cand in (2 * pp_stages, pp_stages):
            if batch % cand == 0:
                microbatches = cand
                print(f"pp={pp_stages}: pipeline microbatches defaulted to "
                      f"{microbatches}")
                break
    if pp_stages > 1 and batch % microbatches != 0:
        raise ValueError(f"--batch {batch} must divide into --microbatches "
                         f"{microbatches} pipeline microbatches")
    if pplan is not None:
        pplan = dataclasses.replace(pplan, microbatches=microbatches)
    pp_schedule = pplan.pp_schedule if pplan is not None \
        else (pp_schedule or "1f1b")
    pp_impl = pplan.pp_impl if pplan is not None else (pp_impl or "shardmap")

    # resolve once: builds the mesh (forcing host devices first) + rules
    plan = pplan.resolve(cfg, global_batch=batch) if pplan is not None \
        else None
    rules = plan.rules if plan is not None else None

    data_dir = prepare_data(out, context=seq, seed=seed)
    loader = ShardedDataLoader(data_dir, global_batch=batch)

    train = TrainConfig(param_dtype="float32", compute_dtype="float32",
                        grad_reduce_dtype="float32", lr_peak=lr,
                        lr_min=lr / 10, warmup_steps=max(steps // 20, 5),
                        total_steps=steps, seq_len=seq, global_batch=batch,
                        seed=seed)
    par = ParallelConfig(microbatches=microbatches, remat_policy=sac,
                         optimizer_sharding=opt_shard,
                         opt_overlap=pplan.opt_overlap
                         if pplan is not None else opt_overlap,
                         pp_stages=pp_stages, pp_schedule=pp_schedule,
                         pp_impl=pp_impl,
                         moe_dispatch=pplan.moe_dispatch
                         if pplan is not None else moe_dispatch)
    # resolve the overlap up front so the header/summary record what the
    # step will actually run (and bad combinations fail with the same error
    # make_train_step would raise)
    from repro.optim.overlap import resolve_opt_overlap
    ov_impl = resolve_opt_overlap(
        par.opt_overlap, opt_shard,
        plan.mesh if plan is not None else None)

    state = init_state(jax.random.PRNGKey(seed), cfg, train, plan=plan,
                       opt_sharding_mode=opt_shard)
    state_sh = train_state_shardings(state.params, rules, opt_shard)

    def build_step(plan_live):
        if plan_live is not None and plan_live.mesh is not None:
            return make_train_step(cfg, par, train, plan=plan_live,
                                   state_shardings=state_sh)
        if plan_live is not None:
            # meshless plan (all axes 1): no shardings to install, but the
            # plan still carries the KernelPlan (backend/tiles) that must
            # scope the step trace — dropping it here would silently ignore
            # --kernel-tiles
            return jax.jit(make_train_step(cfg, par, train, plan=plan_live))
        return jax.jit(make_train_step(cfg, par, train))

    # live state for the rebalance loop: the resolved plan (placement rides
    # on it) and the step compiled against it — a rebalance swaps both
    live = {"plan": plan, "step_fn": build_step(plan)}
    bsh = batch_sharding(rules)

    # ---- telemetry-driven EP rebalancing (parallel/placement.py) ---------
    reb = pplan.rebalance_params() if pplan is not None else None
    controller = None
    if (reb is not None or rebalance_force_at is not None) \
            and cfg.moe is not None:
        from repro.parallel.placement import RebalanceController
        interval, threshold = reb if reb is not None else (steps + 1, 1.0)
        ep_ax = rules.ep_axis if rules is not None else None
        ep = rules.mesh.shape[ep_ax] if (rules is not None and ep_ax
                                         and rules.mesh is not None) else 1
        controller = RebalanceController(
            num_layers=cfg.num_layers, num_experts=cfg.moe.num_experts,
            ep=ep, interval=interval, threshold=threshold)

    def set_placement(placement, state=None, *, prev=None):
        """Swap the live placement: optionally move the state arrays
        (prev -> placement), rebuild the jitted step against it, and keep
        the checkpointer manifest current."""
        if prev is not None and state is not None:
            from repro.parallel.placement import apply_placement
            mv = lambda s: apply_placement(s, prev, placement,
                                           cfg.num_layers,
                                           cfg.moe.num_experts)
            if state_sh is not None:
                mv = jax.jit(mv, donate_argnums=0, out_shardings=state_sh)
            else:
                mv = jax.jit(mv, donate_argnums=0)
            state = mv(state)
        live["plan"] = live["plan"].with_placement(
            None if placement is None or placement.is_identity
            else placement)
        live["step_fn"] = build_step(live["plan"])
        ckpt.placement = None if placement is None or placement.is_identity \
            else placement
        if controller is not None and placement is not None:
            controller.placement = placement
        return state

    inject_hard_at = inject_hard_at if inject_hard_at is not None \
        else _env_int("REPRO_INJECT_HARD_AT")
    inject_soft_at = inject_soft_at if inject_soft_at is not None \
        else _env_int("REPRO_INJECT_SOFT_AT")
    # failure-injection demos checkpoint often enough that the injected
    # failure has something newer than step 0 to restore; explicit intervals
    # on ordinary runs are honored as-is
    if (inject_hard_at is not None or inject_soft_at is not None) \
            and ckpt_interval >= steps:
        ckpt_interval = max(1, steps // 4)
        print(f"injection requested: ckpt interval clamped to {ckpt_interval}")
    ckpt = Checkpointer(os.path.join(out, "ckpt"), interval=ckpt_interval,
                        shardings=state_sh, plan=plan)
    n_nodes = max(2, len(jax.devices()))
    cluster = ClusterManager(n_active=n_nodes, n_buffer=n_buffer)

    # resume if a valid checkpoint exists (resharded onto the jitted placement)
    restored, ck_step = ckpt.restore(state)
    start = 0
    if restored is not None:
        state, start = restored, ck_step + 1   # ckpt holds post-step state
        print(f"resumed from step {start}")
        if ckpt.restored_placement is not None:
            # arrays on disk are already in placed order — adopt the manifest
            # placement without moving anything, rebuild the step against it
            set_placement(ckpt.restored_placement)
            print(f"resumed expert placement (non-identity) from manifest")
    # the loop consumes the loader's iterator; point it at the first step to
    # run so a resumed run replays the exact batch sequence an uninterrupted
    # one would have seen (never batch 0 again)
    loader.load_state_dict({"step": start})
    batches = iter(loader)

    nparams = sum(l.size for l in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={nparams/1e6:.1f}M "
          f"vocab={padded_vocab(cfg)} "
          f"plan={pplan if pplan is not None else 'single'} "
          f"opt_shard={opt_shard} opt_overlap={ov_impl} pp={pp_stages}"
          + (f":{pp_schedule}:{pp_impl}" if pp_stages > 1 else ""))

    injected = {"hard": False, "soft": False}
    history = {}          # keyed by step: replays after restore overwrite
    t0 = time.time()

    def train_one_step(state, step):
        if step == inject_hard_at and not injected["hard"]:
            injected["hard"] = True
            print(f"  !! injected HARD failure on node 0 @ step {step}")
            raise NodeFailure(cluster.active[0].node_id, "hard")
        batch_np = next(batches)     # == loader.batch(step): pure in step
        if cfg.arch_type == "vlm":
            batch_np["image_embeds"] = np.zeros(
                (batch, cfg.num_prefix_embeds, cfg.d_model), np.float32)
        if cfg.arch_type == "audio":
            half = seq // 2
            batch_np = {"frame_embeds": np.random.default_rng(step).normal(
                            size=(batch, half, cfg.d_model)).astype(np.float32),
                        "tokens": batch_np["tokens"][:, :half],
                        "labels": batch_np["labels"][:, :half]}
        batch_dev = jax.tree.map(
            lambda a: jax.device_put(a, bsh) if bsh is not None
            else jnp.asarray(a), batch_np)
        state, metrics = live["step_fn"](state, batch_dev)
        # one host sync per step: batch every fetched metric into a single
        # device_get — per-metric float()/np.asarray() calls would each
        # block and serialize the overlapped step. The MoE telemetry (a
        # scalar + num_experts floats) rides the same batched transfer, so
        # the history artifact keeps its per-step moe_drops/moe_load_max
        # fields at no extra sync cost
        will_log = step % log_every == 0 or step == steps - 1
        fetch = {"loss": metrics["loss"], "lr": metrics["lr"],
                 "grad_norm": metrics["grad_norm"]}
        if "moe_drops" in metrics:
            fetch["moe_drops"] = metrics["moe_drops"]
            fetch["moe_load"] = metrics["moe_load"]
            if controller is not None:
                fetch["moe_counts"] = metrics["moe_counts"]
        vals = jax.device_get(fetch)
        loss = float(vals["loss"])
        gnorm = float(vals["grad_norm"])
        per_rank = [loss]
        if step == inject_soft_at and not injected["soft"]:
            injected["soft"] = True
            print(f"  !! injected SOFT failure (NaN) on node 1 @ step {step}")
            per_rank = [loss, float("nan")]
        history[step] = {"step": step, "loss": loss,
                         "lr": float(vals["lr"]), "grad_norm": gnorm}
        moe_line = ""
        if "moe_drops" in vals:        # per-expert routing telemetry
            drops = float(vals["moe_drops"])
            load = np.asarray(vals["moe_load"])
            history[step]["moe_drops"] = drops
            history[step]["moe_load_max"] = float(load.max()) if load.size \
                else 0.0
            moe_line = (f" drops {drops:.0f} "
                        f"load_max {history[step]['moe_load_max']:.3f}")
        if controller is not None and "moe_counts" in vals:
            # telemetry-driven EP rebalancing: feed the windowed counts to
            # the controller; at a window boundary (or the forced step) move
            # the expert stacks + EPSO states and rebuild the step. The
            # mutated state returns from this step, so the checkpointer
            # saves placed arrays together with the manifest placement.
            imb = controller.observe(np.asarray(vals["moe_counts"]))
            history[step]["moe_imbalance"] = imb
            moe_line += f" imb {imb:.2f}"
            do_force = (rebalance_force_at is not None
                        and step == rebalance_force_at)
            if controller.window_full() or do_force:
                prev = controller.placement
                new_pl = controller.propose(force=do_force)
                if new_pl is not None:
                    state = set_placement(new_pl, state, prev=prev)
                    history[step]["rebalanced"] = True
                    print(f"step {step:5d} rebalanced expert placement "
                          f"(imbalance {imb:.2f}, ep={controller.ep}, "
                          f"event #{controller.rebalances})")
        if will_log:
            dt = time.time() - t0
            print(f"step {step:5d} loss {loss:.4f} gnorm {gnorm:.3f} "
                  f"lr {float(vals['lr']):.2e}{moe_line} ({dt:.1f}s)")
        return state, {"loss": loss, "per_rank_losses": per_rank,
                       "per_rank_grad_norms": [gnorm]}

    def on_relaunch(state, failure, step):
        # rewind the batch stream to the restore point: the iterator re-reads
        # the shared step cursor on every next(), so this re-points it
        loader.load_state_dict({"step": step})
        if controller is not None:
            # re-sync the live placement to whatever the restored checkpoint
            # was written under (identity when the manifest carries none) —
            # the relaunch may roll back across a rebalance event
            from repro.parallel.placement import ExpertPlacement
            target = ckpt.restored_placement or ExpertPlacement.identity(
                cfg.num_layers, cfg.moe.num_experts)
            if target != controller.placement:
                set_placement(target)
            controller.reset_window()
        return state

    state, end_step, relaunches = run_with_failure_handling(
        train_one_step, state=state, checkpointer=ckpt, cluster=cluster,
        num_steps=steps, monitor=NaNMonitor(), start_step=start,
        max_relaunches=max_relaunches, on_relaunch=on_relaunch)

    result = RunResult(history[s] for s in sorted(history))
    result.relaunches = relaunches
    result.replaced = list(cluster.replaced)
    with open(os.path.join(out, "history.json"), "w") as f:
        json.dump(list(result), f)
    summary = {"arch": cfg.name, "steps": end_step, "mesh": mesh,
               "parallel": str(pplan) if pplan is not None else None,
               "opt_shard": opt_shard, "opt_overlap": ov_impl,
               "pp_stages": pp_stages,
               "moe_dispatch": cfg.moe.dispatch if cfg.moe is not None
               else None,
               "pp_schedule": pp_schedule if pp_stages > 1 else None,
               "pp_impl": pp_impl if pp_stages > 1 else None,
               "relaunches": relaunches,
               "replaced": result.replaced,
               "rebalance": pplan.rebalance if pplan is not None else None,
               "rebalances": controller.rebalances if controller is not None
               else 0,
               "final_imbalance": next(
                   (history[s].get("moe_imbalance")
                    for s in sorted(history, reverse=True)
                    if "moe_imbalance" in history[s]), None),
               "final_loss": result[-1]["loss"] if result else None}
    with open(os.path.join(out, "summary.json"), "w") as f:
        json.dump(summary, f)
    if relaunches:
        print(f"completed with {relaunches} relaunch(es); node swaps: "
              f"{result.replaced}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mula-1b")
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--out", default="runs/default")
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, "naive", "dense_capacity", "fsmoe"])
    ap.add_argument("--fur", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--sac", default="block")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--parallel", default=None,
                    help="declarative ParallelPlan spec, e.g. "
                         "'dp=2,pp=2,ep=2' or 'dp=2,ep=2,tp=2' (expert-TP); "
                         "axes: dp, pp, ep, tp, pod; options: opt=, "
                         "schedule=, moe=, tiles=, mb=, fsdp. Forces the "
                         "device "
                         "product "
                         "as CPU host devices; pp>1 enables the jitted "
                         "pipeline schedule")
    ap.add_argument("--mesh", default=None,
                    help="LEGACY simulated device mesh: '4,2' = (data, "
                         "model), '2,2,2' = (data, pp, model); translated "
                         "to a ParallelPlan (MoE: model axis -> ep when "
                         "divisible, else tp). Prefer --parallel")
    ap.add_argument("--opt-shard", default=None,
                    choices=["none", "so", "epso"],
                    help="optimizer-state sharding (paper §3.2); overrides "
                         "a --parallel spec's opt= option (unset = spec "
                         "decides, default none)")
    ap.add_argument("--opt-overlap", default=None,
                    choices=["auto", "off", "ring", "xla"],
                    help="overlapped optimizer collectives (optim/overlap): "
                         "'auto' (default) runs the bucketed ppermute-ring "
                         "update for epso on a real mesh; 'ring'/'xla' force "
                         "an impl for so/epso; 'off' keeps the eager "
                         "GSPMD-derived update. Overrides a --parallel "
                         "spec's overlap= option")
    ap.add_argument("--pp-schedule", default=None,
                    choices=["gpipe", "1f1b"],
                    help="pipeline microbatch schedule when the plan has a "
                         "pp axis (paper §2.2: Mula-100B/220B train 1f1b); "
                         "overrides a --parallel spec's schedule= option")
    ap.add_argument("--pp-impl", default=None,
                    choices=["shardmap", "masked"],
                    help="pipeline executor: 'shardmap' (default) runs "
                         "per-stage programs over the 'pp' axis — only "
                         "stage 0 embeds, only the last stage runs the "
                         "vocab-sized head+CE; 'masked' is the legacy "
                         "single-program SPMD executor. Overrides a "
                         "--parallel spec's impl= option")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=["capacity", "dropless"],
                    help="MoE token dispatch: 'capacity' (slot pool sized by "
                         "capacity_factor, over-capacity tokens dropped) or "
                         "'dropless' (pool sized for the worst-case routing, "
                         "no drops, naive-exact math). Overrides both the "
                         "model's MoEConfig.dispatch and a --parallel spec's "
                         "moe= option")
    ap.add_argument("--kernel-tiles", default=None,
                    help="Pallas kernel tile selection: 'auto' resolves "
                         "tiles per (kernel, shape bucket) from the "
                         "committed tuning table "
                         "(src/repro/kernels/tuning_table.json; regenerate "
                         "with benchmarks/bench_kernels.py --write-table), "
                         "or an explicit 'TMxTKxTN' triple, e.g. "
                         "128x512x512. Overrides a --parallel spec's "
                         "tiles= option")
    ap.add_argument("--rebalance", default=None,
                    help="telemetry-driven EP rebalancing "
                         "(parallel/placement.py): 'off' or 'N:threshold' "
                         "(e.g. 50:1.25 — every 50 steps, re-place the "
                         "experts over the EP axis when the windowed "
                         "max/mean rank load exceeds 1.25). Numerics-"
                         "preserving data movement: losses are unchanged "
                         "across a rebalance event. Overrides a --parallel "
                         "spec's rebalance= option")
    ap.add_argument("--rebalance-force-at", type=int, default=None,
                    help="force one rebalance event after this step "
                         "regardless of threshold (tests/goldens)")
    ap.add_argument("--log-every", type=int, default=10,
                    help="print the step line (loss/gnorm/lr + MoE routing "
                         "telemetry: drops, max expert load) every N steps")
    ap.add_argument("--n-buffer", type=int, default=2,
                    help="buffer nodes for hard-failure replacement")
    ap.add_argument("--inject-hard-at", type=int, default=None,
                    help="inject one hard node failure at this step "
                         "(also REPRO_INJECT_HARD_AT)")
    ap.add_argument("--inject-soft-at", type=int, default=None,
                    help="inject one soft (NaN) failure at this step "
                         "(also REPRO_INJECT_SOFT_AT)")
    args = ap.parse_args()
    run(args.arch, scale=args.scale, steps=args.steps, batch=args.batch,
        seq=args.seq, out=args.out, lr=args.lr, moe_impl=args.moe_impl,
        fur=args.fur, microbatches=args.microbatches, sac=args.sac,
        d_model=args.d_model, layers=args.layers, seed=args.seed,
        ckpt_interval=args.ckpt_interval, mesh=args.mesh,
        parallel=args.parallel,
        opt_shard=args.opt_shard, opt_overlap=args.opt_overlap,
        pp_schedule=args.pp_schedule,
        pp_impl=args.pp_impl, moe_dispatch=args.moe_dispatch,
        kernel_tiles=args.kernel_tiles,
        rebalance=args.rebalance,
        rebalance_force_at=args.rebalance_force_at,
        log_every=args.log_every, n_buffer=args.n_buffer,
        inject_hard_at=args.inject_hard_at,
        inject_soft_at=args.inject_soft_at)


if __name__ == "__main__":
    main()
