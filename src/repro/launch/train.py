"""End-to-end training launcher.

Wires together the full substrate: data pipeline (tokenize/shuffle/shard +
mmap loader), model zoo, FSMOE, AdamW with SO/EPSO sharding, SAC, dual +
model-only checkpointing, NaN monitoring, and (optionally) a host-device
mesh. Reduced-scale runs reproduce the paper's Figure 1 training curves
(see examples/train_mula.py).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch mula-7b-a1b --scale smoke \
      --steps 100 --batch 8 --seq 128 --out runs/mula7b
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ParallelConfig, TrainConfig, get_config, reduced)
from repro.data import ByteTokenizer, ShardedDataLoader, preprocess_corpus
from repro.checkpoint import Checkpointer
from repro.ft import NaNMonitor, NodeFailure
from repro.train import init_state, make_train_step
from repro.models import padded_vocab


def synthetic_corpus(n_files: int = 4, docs_per_file: int = 64,
                     seed: int = 0):
    """Procedural text corpus: Zipf-ish word soup with structure, so the
    loss curve has signal (byte-level models learn digraph statistics)."""
    rng = np.random.default_rng(seed)
    words = ["the", "model", "expert", "router", "token", "aurora", "tile",
             "pipeline", "gradient", "optimizer", "state", "shard", "mixture",
             "attention", "scan", "chunk", "loss", "batch", "step", "node"]
    probs = 1.0 / np.arange(1, len(words) + 1)
    probs /= probs.sum()
    files = []
    for _ in range(n_files):
        docs = []
        for _ in range(docs_per_file):
            n = int(rng.integers(30, 120))
            docs.append(" ".join(rng.choice(words, size=n, p=probs)) + ".")
        files.append(docs)
    return files


def prepare_data(out_dir: str, *, context: int, seed: int = 0,
                 n_files: int = 4, docs_per_file: int = 256):
    data_dir = os.path.join(out_dir, "data")
    if not os.path.exists(os.path.join(data_dir, "meta.json")):
        preprocess_corpus(synthetic_corpus(n_files, docs_per_file, seed),
                          data_dir, context=context, seed=seed)
    return data_dir


def run(arch: str, *, scale: str = "smoke", steps: int = 100, batch: int = 8,
        seq: int = 128, out: str = "runs/default", lr: float = 1e-3,
        moe_impl: str = None, fur: bool = False, ckpt_interval: int = 50,
        microbatches: int = 1, sac: str = "block", seed: int = 0,
        log_every: int = 10, d_model: int = 256, layers: int = 2,
        d_ff: int = 0, moe_dff: int = 0):
    os.makedirs(out, exist_ok=True)
    cfg = get_config(arch)
    if scale == "smoke":
        cfg = reduced(cfg, layers=layers, d_model=d_model,
                      vocab=ByteTokenizer.VOCAB)
    else:
        cfg = dataclasses.replace(cfg, vocab_size=ByteTokenizer.VOCAB)
    if d_ff:
        cfg = dataclasses.replace(cfg, d_ff=d_ff)
    if cfg.moe is not None and (moe_impl or fur or moe_dff):
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, moe_impl=moe_impl or cfg.moe.moe_impl,
            forced_uniform_routing=fur,
            d_ff_expert=moe_dff or cfg.moe.d_ff_expert))

    data_dir = prepare_data(out, context=seq, seed=seed)
    loader = ShardedDataLoader(data_dir, global_batch=batch)

    train = TrainConfig(param_dtype="float32", compute_dtype="float32",
                        grad_reduce_dtype="float32", lr_peak=lr,
                        lr_min=lr / 10, warmup_steps=max(steps // 20, 5),
                        total_steps=steps, seq_len=seq, global_batch=batch,
                        seed=seed)
    par = ParallelConfig(microbatches=microbatches, remat_policy=sac)

    state = init_state(jax.random.PRNGKey(seed), cfg, train)
    step_fn = jax.jit(make_train_step(cfg, par, train))
    ckpt = Checkpointer(os.path.join(out, "ckpt"), interval=ckpt_interval)
    monitor = NaNMonitor()

    # resume if a valid checkpoint exists
    restored, ck_step = ckpt.restore(state)
    start = 0
    if restored is not None:
        state, start = restored, ck_step + 1   # ckpt holds post-step state
        print(f"resumed from step {start}")

    nparams = sum(l.size for l in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={nparams/1e6:.1f}M vocab={padded_vocab(cfg)}")

    history = []
    t0 = time.time()
    for step in range(start, steps):
        batch_np = loader.batch(step)
        if cfg.arch_type == "vlm":
            batch_np["image_embeds"] = np.zeros(
                (batch, cfg.num_prefix_embeds, cfg.d_model), np.float32)
        if cfg.arch_type == "audio":
            half = seq // 2
            batch_np = {"frame_embeds": np.random.default_rng(step).normal(
                            size=(batch, half, cfg.d_model)).astype(np.float32),
                        "tokens": batch_np["tokens"][:, :half],
                        "labels": batch_np["labels"][:, :half]}
        state, metrics = step_fn(state, jax.tree.map(jnp.asarray, batch_np))
        loss = float(metrics["loss"])
        monitor.check([loss], [float(metrics["grad_norm"])], step=step)
        ckpt.maybe_save(state, state.params, step)
        history.append({"step": step, "loss": loss,
                        "lr": float(metrics["lr"]),
                        "grad_norm": float(metrics["grad_norm"])})
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)")
    with open(os.path.join(out, "history.json"), "w") as f:
        json.dump(history, f)
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mula-1b")
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--out", default="runs/default")
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, "naive", "dense_capacity", "fsmoe"])
    ap.add_argument("--fur", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--sac", default="block")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.arch, scale=args.scale, steps=args.steps, batch=args.batch,
        seq=args.seq, out=args.out, lr=args.lr, moe_impl=args.moe_impl,
        fur=args.fur, microbatches=args.microbatches, sac=args.sac,
        d_model=args.d_model, layers=args.layers, seed=args.seed)


if __name__ == "__main__":
    main()
