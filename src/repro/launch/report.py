"""Render dryrun_results.json as the EXPERIMENTS.md §Roofline tables.

    PYTHONPATH=src python -m repro.launch.report dryrun_results.json [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json


def fmt_seconds(s: float) -> str:
    if s >= 1:
        return f"{s:7.2f}s "
    return f"{s * 1e3:7.1f}ms"


def render(records, mesh=None):
    rows = [r for r in records if mesh is None or r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = []
    out.append("| arch | shape | mesh | compute | memory | collective | "
               "dominant | useful | GF/chip | GB/chip | coll GB/chip | "
               "peak GiB/dev |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        peak = (r.get("arg_bytes", 0) + r.get("temp_bytes", 0)) / 2 ** 30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_seconds(r['compute_s'])} | {fmt_seconds(r['memory_s'])} "
            f"| {fmt_seconds(r['collective_s'])} | **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['hlo_gflops_per_chip']:,.0f} "
            f"| {r['hlo_gbytes_per_chip']:,.0f} "
            f"| {r['coll_gbytes_per_chip']:,.1f} | {peak:,.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    with open(args.path) as f:
        data = json.load(f)
    print(render(data["records"], args.mesh))
    if data.get("failures"):
        print("\nFAILURES:")
        for f_ in data["failures"]:
            print(" ", f_)


if __name__ == "__main__":
    main()
