"""Roofline-term derivation from compiled dry-run artifacts (spec §ROOFLINE).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

``cost_analysis`` FLOPs/bytes on a SPMD module are per-device; we convert to
global by multiplying by the device count. Collective bytes are parsed from
the compiled HLO text: for each all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction we take the (per-device) result
shape and apply a ring-model factor using the replica-group size n:

    all-gather          bytes = result x (n-1)/n          (received)
    reduce-scatter      bytes = result x (n-1)            (operand streamed)
    all-reduce          bytes = 2 x result x (n-1)/n      (RS + AG phases)
    all-to-all          bytes = result x (n-1)/n
    collective-permute  bytes = result

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (values given by the task spec).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9\[\],{}x ]+?)\s*(?:\))?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\s*[,)]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [ngroups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return len(first.split(","))
    return 2


def collective_bytes(hlo_text: str) -> dict:
    """Per-device communicated bytes by collective kind (ring model)."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:   # async pair: count only the -start
            continue
        type_str, kind = m.group(1), m.group(2)
        rb = _shape_bytes(type_str)
        n = _group_size(line)
        if kind == "all-gather":
            b = rb * (n - 1) / n
        elif kind == "reduce-scatter":
            b = rb * (n - 1)
        elif kind == "all-reduce":
            b = 2 * rb * (n - 1) / n
        elif kind == "all-to-all":
            b = rb * (n - 1) / n
        else:
            b = rb
        out[kind] += b
    out["total"] = sum(out.values())
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops_per_chip: float
    hlo_gbytes_per_chip: float
    coll_gbytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float = 0.0
    bytes_per_device: float = 0.0
    coll_breakdown: dict = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled HLO FLOPs — how much of the compiled
        compute is 'useful' (catches remat/capacity/attention overhead)."""
        total = self.hlo_gflops_per_chip * 1e9 * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_gflops_per_chip": self.hlo_gflops_per_chip,
            "hlo_gbytes_per_chip": self.hlo_gbytes_per_chip,
            "coll_gbytes_per_chip": self.coll_gbytes_per_chip,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "bytes_per_device": self.bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
        }


def derive(arch, shape, mesh_name, chips, cost, hlo_text,
           model_flops=0.0, bytes_per_device=0.0) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_gflops_per_chip=flops / 1e9,
        hlo_gbytes_per_chip=byts / 1e9,
        coll_gbytes_per_chip=coll["total"] / 1e9,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll["total"] / LINK_BW,
        model_flops=model_flops,
        bytes_per_device=bytes_per_device,
        coll_breakdown={k: v for k, v in coll.items() if k != "total"},
    )


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for training,
    2·N·D for inference (fwd only), D = processed tokens."""
    n = cfg.active_param_count()
    seq = shape.seq_len
    if getattr(cfg, "is_encoder_decoder", False):
        seq = seq // 2    # enc/dec each see half the token budget
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * seq
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * seq
    return 2.0 * n * shape.global_batch          # decode: one token
