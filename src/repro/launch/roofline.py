"""Roofline-term derivation from compiled dry-run artifacts (spec §ROOFLINE).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

``cost_analysis`` FLOPs/bytes on a SPMD module are per-device; we convert to
global by multiplying by the device count. Collective bytes are parsed from
the compiled HLO text: for each all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction we take the (per-device) result
shape and apply a ring-model factor using the replica-group size n:

    all-gather          bytes = result x (n-1)/n          (received)
    reduce-scatter      bytes = result x (n-1)            (operand streamed)
    all-reduce          bytes = 2 x result x (n-1)/n      (RS + AG phases)
    all-to-all          bytes = result x (n-1)/n
    collective-permute  bytes = result

Hardware constants live in the :data:`HARDWARE` registry (``HardwareSpec``):
TPU v5e (the original task-spec numbers, still exported as the module-level
``PEAK_FLOPS``/``HBM_BW``/``LINK_BW`` constants), the Aurora PVC tile from
the source paper's hardware table, and a calibrated ``sim-cpu`` spec for
the forced-host-device CI container (see ``calibrate_sim_cpu``).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field


@dataclass(frozen=True)
class HardwareSpec:
    """One machine's roofline constants + on-chip fast-memory budget.

    ``vmem_bytes`` is the per-core software-managed fast memory a Pallas
    kernel tiles into (TPU VMEM; the closest PVC analog is the per-tile L2
    slice; for sim-cpu a per-core L2-ish figure). The kernel autotuner
    prunes tile candidates whose double-buffered working set exceeds it,
    and ``KernelPlan``'s guardrail warns/errors on the same budget.
    """
    name: str
    peak_flops: float          # bf16 FLOP/s per chip/tile
    hbm_bw: float              # bytes/s per chip/tile
    link_bw: float             # bytes/s per link
    vmem_bytes: int            # on-chip fast memory per core (see above)
    description: str = ""

    def roofline_time(self, flops: float, byts: float) -> float:
        """Seconds the roofline model predicts for one kernel invocation:
        max of the compute and memory terms (no overlap slack)."""
        return max(flops / self.peak_flops, byts / self.hbm_bw)


HARDWARE = {
    # the original task-spec machine (kept as the default)
    "tpu-v5e": HardwareSpec(
        "tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9,
        vmem_bytes=16 * 2**20,
        description="TPU v5e: 197 TF/s bf16, 819 GB/s HBM, ~50 GB/s ICI "
                    "link, 16 MiB VMEM/core"),
    # one tile of the Aurora node's Intel Data Center GPU Max 1550 (the
    # paper's hardware table: 2 tiles/GPU, 6 GPUs/node) — per-tile halves
    # of the 832 TF/s bf16 and 3.28 TB/s HBM2e figures; Xe Link per-link
    # bandwidth; per-tile L2 slice as the fast-memory budget
    "pvc-tile": HardwareSpec(
        "pvc-tile", peak_flops=416e12, hbm_bw=1640e9, link_bw=26e9,
        vmem_bytes=204 * 2**20,
        description="Aurora PVC tile (Max 1550 / 2): 416 TF/s bf16, "
                    "1.64 TB/s HBM2e, ~26 GB/s Xe Link, 204 MiB L2/tile"),
    # the CI container's forced-host-device simulation. Numbers from
    # calibrate_sim_cpu() on the reference runner (single-process XLA CPU
    # matmul throughput + memcpy bandwidth), committed so analytics are
    # deterministic; re-calibrate with bench_kernels.py (recorded in
    # BENCH_kernels.json) when the runner changes.
    "sim-cpu": HardwareSpec(
        "sim-cpu", peak_flops=6.5e10, hbm_bw=1.1e10, link_bw=1e9,
        vmem_bytes=32 * 2**20,
        description="calibrated CI container CPU: ~65 GF/s f32 matmul, "
                    "~11 GB/s copy bandwidth (see calibrate_sim_cpu)"),
}


def get_hardware(name: str) -> HardwareSpec:
    if name not in HARDWARE:
        raise ValueError(f"unknown hardware spec {name!r}; registered: "
                         f"{', '.join(sorted(HARDWARE))}")
    return HARDWARE[name]


def gmm_working_set_bytes(tile_m: int, tile_k: int, tile_n: int, *,
                          in_bytes: int = 2, acc_bytes: int = 4,
                          double_buffer: bool = True) -> int:
    """Analytic VMEM working set of one grouped-matmul grid step: the lhs
    and rhs input tiles (double-buffered — the DMA of step i+1 overlaps the
    compute of step i) plus the f32 accumulator tile (not double-buffered;
    it lives across the k loop). This is the budget the autotuner prunes
    candidates against and ``KernelPlan``'s guardrail checks."""
    mult = 2 if double_buffer else 1
    return ((tile_m * tile_k + tile_k * tile_n) * in_bytes * mult
            + tile_m * tile_n * acc_bytes)


def calibrate_sim_cpu(*, n: int = 1024, reps: int = 5) -> HardwareSpec:
    """Measure this process's achievable f32 matmul FLOP/s and copy
    bandwidth (median-of-N, block_until_ready) and return a HardwareSpec
    for it. Used by bench_kernels.py to stamp the calibration the achieved
    fractions in BENCH_kernels.json were computed against."""
    import time

    import jax
    import jax.numpy as jnp

    x = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)

    def median_time(fn, *args):
        out = fn(*args)
        jax.block_until_ready(out)
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2]

    mm = jax.jit(lambda a: a @ a)
    t_mm = median_time(mm, x)
    flops = 2.0 * n ** 3 / max(t_mm, 1e-9)
    cp = jax.jit(lambda a: a + 1.0)
    t_cp = median_time(cp, x)
    bw = 2.0 * x.nbytes / max(t_cp, 1e-9)      # read + write
    base = HARDWARE["sim-cpu"]
    return HardwareSpec("sim-cpu", peak_flops=flops, hbm_bw=bw,
                        link_bw=base.link_bw, vmem_bytes=base.vmem_bytes,
                        description=f"calibrated in-process: matmul "
                                    f"{flops / 1e9:.1f} GF/s, copy "
                                    f"{bw / 1e9:.1f} GB/s")


_V5E = HARDWARE["tpu-v5e"]
PEAK_FLOPS = _V5E.peak_flops   # bf16 per chip (legacy constants: v5e)
HBM_BW = _V5E.hbm_bw           # bytes/s per chip
LINK_BW = _V5E.link_bw         # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "f8e8m0fnu": 1, "f4e2m1fn": 1, "s4": 1, "u4": 1,
}
# bytes assumed for a dtype token we do not recognize: conservative (f32-
# sized) so the collective term over-counts rather than silently dropping
# the instruction (the old behavior — see test_roofline.py)
_UNKNOWN_DTYPE_BYTES = 4

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9\[\],{}x ]+?)\s*(?:\))?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\s*[,)]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_bytes(type_str: str, unknown: set | None = None) -> int:
    """Bytes of an HLO result type (sums tuple components). A dtype token
    we don't recognize is counted at a conservative 4 bytes/element —
    never silently dropped — and recorded in ``unknown`` when given."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            if unknown is not None:
                unknown.add(dt)
            nb = _UNKNOWN_DTYPE_BYTES
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [ngroups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return len(first.split(","))
    return 2


@dataclass(frozen=True)
class CollectiveInstr:
    """One collective instruction from a compiled-HLO walk.

    ``result_bytes`` is the (per-device) payload of the instruction's
    result type (tuple components summed); ``ring_bytes`` applies the
    ring-model factor for ``group_size`` (the module-docstring table).
    Async ``-start``/``-done`` pairs surface as ONE record (the start).
    """
    kind: str            # one of COLLECTIVE_KINDS
    result_bytes: int
    group_size: int
    ring_bytes: float
    is_async: bool = False


def ring_model_bytes(kind: str, result_bytes: float, n: int) -> float:
    """Ring-model communicated bytes for one collective (see module
    docstring for the per-kind factors)."""
    if kind == "all-gather":
        return result_bytes * (n - 1) / n
    if kind == "reduce-scatter":
        return result_bytes * (n - 1)
    if kind == "all-reduce":
        return 2 * result_bytes * (n - 1) / n
    if kind == "all-to-all":
        return result_bytes * (n - 1) / n
    if kind == "collective-permute":
        return float(result_bytes)
    raise ValueError(f"unknown collective kind {kind!r}")


def walk_collectives(hlo_text: str, unknown: set | None = None):
    """Yield a :class:`CollectiveInstr` per collective instruction in
    ``hlo_text`` — the one HLO-walking pass shared by the roofline's
    ``collective_bytes`` totals and the static analyzer's census
    (``repro.analysis.census``), so their byte accounting can never
    diverge. ``-done`` halves of async pairs are skipped; dtype tokens not
    in ``_DTYPE_BYTES`` are counted at 4 B/elt and recorded in ``unknown``
    when given."""
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:   # async pair: count only the -start
            continue
        type_str, kind = m.group(1), m.group(2)
        rb = _shape_bytes(type_str, unknown)
        n = _group_size(line)
        yield CollectiveInstr(kind=kind, result_bytes=rb, group_size=n,
                              ring_bytes=ring_model_bytes(kind, rb, n),
                              is_async="-start(" in line)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device communicated bytes by collective kind (ring model).

    The returned dict maps kind -> bytes plus two extra keys: ``total``
    (sum over the kinds) and ``unknown_dtypes`` — a sorted list of dtype
    tokens that appeared in a collective's result shape but are not in
    ``_DTYPE_BYTES``. Those elements are counted at a conservative
    4 bytes each rather than dropped (the pre-fix behavior undercounted
    the collective term to zero for e.g. fp8 all-gathers).
    """
    out = {k: 0.0 for k in COLLECTIVE_KINDS}
    unknown: set = set()
    for instr in walk_collectives(hlo_text, unknown):
        out[instr.kind] += instr.ring_bytes
    out["total"] = sum(out.values())
    out["unknown_dtypes"] = sorted(unknown)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops_per_chip: float
    hlo_gbytes_per_chip: float
    coll_gbytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float = 0.0
    bytes_per_device: float = 0.0
    coll_breakdown: dict = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled HLO FLOPs — how much of the compiled
        compute is 'useful' (catches remat/capacity/attention overhead)."""
        total = self.hlo_gflops_per_chip * 1e9 * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_gflops_per_chip": self.hlo_gflops_per_chip,
            "hlo_gbytes_per_chip": self.hlo_gbytes_per_chip,
            "coll_gbytes_per_chip": self.coll_gbytes_per_chip,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "bytes_per_device": self.bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
        }


def derive(arch, shape, mesh_name, chips, cost, hlo_text,
           model_flops=0.0, bytes_per_device=0.0) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_gflops_per_chip=flops / 1e9,
        hlo_gbytes_per_chip=byts / 1e9,
        coll_gbytes_per_chip=coll["total"] / 1e9,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll["total"] / LINK_BW,
        model_flops=model_flops,
        bytes_per_device=bytes_per_device,
        coll_breakdown={k: v for k, v in coll.items() if k != "total"},
    )


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for training,
    2·N·D for inference (fwd only), D = processed tokens."""
    n = cfg.active_param_count()
    seq = shape.seq_len
    if getattr(cfg, "is_encoder_decoder", False):
        seq = seq // 2    # enc/dec each see half the token budget
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * seq
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * seq
    return 2.0 * n * shape.global_batch          # decode: one token
