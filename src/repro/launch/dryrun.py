import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (spec §MULTI-POD DRY-RUN).

For every (architecture x input shape) combination this lowers + compiles
the appropriate step (train_step / prefill_step / serve_step) against the
production mesh — 16x16 ("data","model") single-pod and 2x16x16
("pod","data","model") multi-pod — using ShapeDtypeStruct stand-ins (no
allocation), prints memory_analysis() and cost_analysis(), and derives the
three roofline terms (launch/roofline.py).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod/--single-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results.json

Plan resolution (no compile — shape-only; the CI smoke step):
    PYTHONPATH=src python -m repro.launch.dryrun --parallel dp=2,pp=2,ep=2 \
        --arch mula-7b-a1b
prints the resolved ParallelPlan: mesh axes, batch placement, the
per-parameter PartitionSpec table (param + optimizer state) and projected
bytes/device.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import (ARCH_REGISTRY, ASSIGNED_ARCHS, INPUT_SHAPES,
                           ParallelConfig, TrainConfig, get_config)
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as RL
from repro.launch.specs import input_specs, decode_input_specs, state_specs
from repro.parallel.plan import ParallelPlan, ResolvedPlan
from repro.parallel.sharding import make_rules
from repro.train.trainer import (make_train_step, make_prefill_step,
                                 make_serve_step)

# Per-arch parallel policy (DESIGN §6/§7). fsdp: shard params over the data
# axes too (ZeRO-3) — required for 405B-class; microbatches bound activation
# memory for the big training shapes.
ARCH_PARALLEL = {
    "llama3-405b": dict(fsdp=True, microbatches=16),
    "dbrx-132b": dict(fsdp=True, microbatches=8),
    "mixtral-8x7b": dict(fsdp=False, microbatches=4),
    "moonshot-v1-16b-a3b": dict(fsdp=False, microbatches=2),
    "zamba2-7b": dict(fsdp=False, microbatches=2),
    "falcon-mamba-7b": dict(fsdp=False, microbatches=2),
    "deepseek-7b": dict(fsdp=False, microbatches=2),
    "starcoder2-3b": dict(fsdp=False, microbatches=1),
    "seamless-m4t-medium": dict(fsdp=False, microbatches=1),
    "phi-3-vision-4.2b": dict(fsdp=False, microbatches=1),
    "mula-1b": dict(fsdp=False, microbatches=1),
    "mula-7b-a1b": dict(fsdp=False, microbatches=1),
    "mula-20b-a2b": dict(fsdp=False, microbatches=2),
    "mula-100b-a7b": dict(fsdp=True, microbatches=4),
    "mula-220b-a10b": dict(fsdp=True, microbatches=8),
}

# long_500k runs only for sub-quadratic archs (DESIGN §6)
LONG_OK = {"zamba2-7b", "falcon-mamba-7b", "mixtral-8x7b", "starcoder2-3b"}


def combos(archs=None):
    archs = archs or ASSIGNED_ARCHS
    for a in archs:
        cfg = get_config(a)
        for s in INPUT_SHAPES.values():
            if s.name == "long_500k" and a not in LONG_OK:
                continue
            if s.kind == "decode" and cfg.is_encoder_decoder and False:
                continue  # enc-dec decode is supported (self+cross cache)
            yield a, s


def lower_one(arch: str, shape_name: str, *, multi_pod: bool,
              opt_mode: str = "epso", role=None, sac=None,
              microbatches=None, verbose=True, moe_opts: dict = None):
    cfg = get_config(arch)
    if moe_opts and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **moe_opts))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    pol = ARCH_PARALLEL.get(arch, {})
    fsdp = pol.get("fsdp", False)
    nmb = microbatches if microbatches is not None else (
        pol.get("microbatches", 1) if shape.kind == "train" else 1)

    rules = make_rules(cfg, mesh, kind=shape.kind, fsdp=fsdp, role=role,
                       global_batch=shape.global_batch)
    # the production meshes carry roles/axis names no plan token spells, so
    # wrap the hand-built rules in a ResolvedPlan rather than riding the
    # deprecated rules=/mesh= threading into the step builders
    rplan = ResolvedPlan(
        plan=ParallelPlan(opt_shard=opt_mode if shape.kind == "train"
                          else "none", fsdp=fsdp, microbatches=max(nmb, 1)),
        mesh=mesh, rules=rules)
    # microbatches must keep the per-microbatch batch shardable
    shards = 1
    for a in rules.batch_axes:
        shards *= mesh.shape[a]
    while nmb > 1 and shape.global_batch % (nmb * shards) != 0:
        nmb //= 2
    train = TrainConfig(param_dtype="bfloat16", compute_dtype="bfloat16",
                        seq_len=shape.seq_len, global_batch=shape.global_batch)

    if shape.kind == "train":
        par = ParallelConfig(remat_policy=sac if sac is not None else "block",
                             microbatches=nmb,
                             optimizer_sharding=opt_mode)
        step = make_train_step(cfg, par, train, plan=rplan)
        state = state_specs(cfg, train, rules, opt_mode)
        batch = input_specs(cfg, shape, rules)
        args = (state, batch)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, plan=rplan)
        params = state_specs(cfg, train, rules, opt_mode).params
        batch = input_specs(cfg, shape, rules)
        args = (params, batch)
    else:  # decode
        step = make_serve_step(cfg, plan=rplan)
        params = state_specs(cfg, train, rules, opt_mode).params
        tokens, cache, index = decode_input_specs(cfg, shape, rules)
        args = (params, tokens, cache, index)

    t0 = time.time()
    # the train step comes back already jitted (the plan carries an opt
    # mode); prefill/serve come back raw
    lowered = (step if hasattr(step, "lower") else jax.jit(step)).lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    bytes_per_dev = getattr(mem, "temp_size_in_bytes", 0) + \
        getattr(mem, "argument_size_in_bytes", 0)

    # roofline terms via scan-free probes (launch/costmodel.py) — XLA's
    # cost_analysis counts while bodies once, so the full module's numbers
    # under-report by the trip counts; probes are exact.
    from repro.launch import costmodel as CM
    cm = CM.analyze(cfg, shape, rules, opt_mode=opt_mode,
                    sac=sac if sac is not None else "block",
                    microbatches=nmb)
    rl = RL.Roofline(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16", chips=chips,
        hlo_gflops_per_chip=cm["flops_per_chip"] / 1e9,
        hlo_gbytes_per_chip=cm["bytes_per_chip"] / 1e9,
        coll_gbytes_per_chip=cm["coll_per_chip"].get("total", 0.0) / 1e9,
        compute_s=cm["flops_per_chip"] / RL.PEAK_FLOPS,
        memory_s=cm["bytes_per_chip"] / RL.HBM_BW,
        collective_s=cm["coll_per_chip"].get("total", 0.0) / RL.LINK_BW,
        model_flops=RL.model_flops_estimate(cfg, shape),
        bytes_per_device=bytes_per_dev,
        coll_breakdown={k: v for k, v in cm["coll_per_chip"].items()
                        if k != "total"})
    rec = rl.row()
    rec.update({
        "opt_mode": opt_mode, "fsdp": fsdp, "microbatches": nmb,
        "role": rules.tp_axis and "tp/etp" or (rules.ep_axis and "ep"),
        "batch_axes": list(rules.batch_axes),
        "lower_s": round(t1 - t0, 1), "compile_s": round(t2 - t1, 1),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "arg_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
    })
    if verbose:
        print(f"[{arch} x {shape_name} @ {rec['mesh']}] "
              f"ok lower={rec['lower_s']}s compile={rec['compile_s']}s")
        print(f"  memory_analysis: args={rec['arg_bytes']/2**30:.2f}GiB "
              f"temp={rec['temp_bytes']/2**30:.2f}GiB "
              f"out={rec['output_bytes']/2**30:.2f}GiB (per device)")
        print(f"  cost_analysis: {rl.hlo_gflops_per_chip:.1f} GF/chip, "
              f"{rl.hlo_gbytes_per_chip:.2f} GB/chip, "
              f"coll {rl.coll_gbytes_per_chip:.3f} GB/chip")
        print(f"  roofline: compute={rl.compute_s*1e3:.2f}ms "
              f"memory={rl.memory_s*1e3:.2f}ms "
              f"collective={rl.collective_s*1e3:.2f}ms "
              f"-> dominant={rl.dominant} "
              f"useful={rl.useful_flops_ratio:.2f}")
    return rec


def print_parallel_plan(spec: str, arch: str, *, global_batch: int = 256,
                        train_cfg=None, kernel_table: str = None) -> str:
    """Resolve a --parallel spec against ``arch`` and print the plan:
    axes, per-param placement, projected bytes/device, and (for MoE archs)
    the per-kernel roofline attribution table. Shape-only
    (jax.eval_shape) — no allocation, no compile; safe as a CI smoke.

    ``kernel_table``: path to a tuning table for the measured columns,
    'none' to force prediction-only, None for the committed default."""
    from repro.parallel.plan import ParallelPlan
    cfg = get_config(arch)
    pplan = ParallelPlan.parse(spec)
    cfg = pplan.apply_to_model(cfg)   # moe= in the spec pins the dispatch
    plan = pplan.resolve(cfg, train_cfg, global_batch=global_batch)
    text = plan.describe(cfg)
    print(f"== resolved plan for {arch} (global_batch={global_batch}) ==")
    print(text)
    if pplan.pp > 1:
        text += "\n" + print_per_stage_costs(cfg, pplan,
                                             global_batch=global_batch)
    if getattr(cfg, "is_moe", False):
        text += "\n" + print_per_kernel_costs(
            cfg, pplan, global_batch=global_batch, kernel_table=kernel_table)
    return text


def print_per_kernel_costs(cfg, pplan, *, global_batch: int,
                           seq: int = 2048, kernel_table: str = None) -> str:
    """Per-kernel roofline attribution (costmodel.per_kernel_costs): one
    row per expert-path kernel with analytic FLOPs/bytes/AI, the predicted
    time on the plan's HardwareSpec, and — when a tuning table entry covers
    the kernel — the autotuned tiles, its measured time on the bench shape,
    and the achieved-vs-peak fraction."""
    from repro.kernels import autotune
    from repro.launch.costmodel import per_kernel_costs
    if kernel_table == "none":
        table = None
    elif kernel_table:
        table = autotune.TuningTable.load(kernel_table)
    else:
        table = autotune.active_table()
    rep = per_kernel_costs(cfg, pplan, global_batch=global_batch, seq=seq,
                           table=table)
    lines = [f"-- per-kernel roofline attribution [hw={rep['hw']}] "
             f"({rep.get('per', '')}; tuning table: "
             f"{'none' if table is None else table.path or 'in-memory'}) --"]
    if not rep["rows"]:
        lines.append(rep.get("note", "no kernel rows"))
    else:
        lines.append(f"{'kernel':16s} {'gflops':>8s} {'gbytes':>8s} "
                     f"{'AI':>7s} {'pred':>9s} {'bound':>7s} "
                     f"{'tuned tiles':>14s} {'measured':>9s} {'ach%':>6s}")
        for r in rep["rows"]:
            tiles = "x".join(str(t) for t in r["tiles"]) \
                if r.get("tiles") else "-"
            meas = f"{r['measured_ms']:7.1f}ms" if r.get("measured_ms") \
                is not None else "-"
            ach = f"{100 * r['achieved_frac']:5.1f}%" \
                if r.get("achieved_frac") is not None else "-"
            lines.append(
                f"{r.get('kernel_instance', r['kernel']):16s} "
                f"{r['flops'] / 1e9:8.2f} {r['bytes'] / 1e9:8.3f} "
                f"{r['ai']:7.1f} {r['pred_ms']:7.3f}ms {r['bound']:>7s} "
                f"{tiles:>14s} {meas:>9s} {ach:>6s}")
        pred_total = sum(r["pred_ms"] for r in rep["rows"])
        lines.append(f"predicted MoE-layer fwd total: {pred_total:.3f}ms "
                     f"per device ({rep['tokens_per_device']} tokens/dev)")
    text = "\n".join(lines)
    print(text)
    return text


def print_per_stage_costs(cfg, pplan, *, global_batch: int,
                          seq: int = 2048) -> str:
    """Per-stage projected FLOPs/bytes for a pp>1 plan — makes the head
    compute the shard_map executor reclaims visible without compiling.
    Prints the plan's executor next to the masked baseline."""
    from repro.launch.costmodel import per_stage_costs
    lines = []
    n_mb = max(pplan.microbatches, 2 * pplan.pp)
    impls = [pplan.pp_impl] + (["masked"] if pplan.pp_impl != "masked"
                               else [])
    reps = {}
    for impl in impls:
        rep = per_stage_costs(cfg, pp=pplan.pp, microbatches=n_mb,
                              seq=seq, global_batch=global_batch,
                              pp_impl=impl, schedule=pplan.pp_schedule)
        reps[impl] = rep
        lines.append(f"-- per-stage projection [impl={impl}] "
                     f"(seq={seq}, mb={rep['microbatches']}, "
                     f"ticks={rep['ticks']}) --")
        lines.append(f"{'stage':>5s} {'role':32s} {'blocks':>12s} "
                     f"{'head+ce':>12s} {'total':>12s} {'act-bytes':>11s}")
        for st in rep["stages"]:
            lines.append(
                f"{st['stage']:5d} {st['role']:32s} "
                f"{st['block_gflops']:10.1f}GF {st['head_gflops']:10.1f}GF "
                f"{st['total_gflops']:10.1f}GF {st['act_gbytes']:8.2f}GiB")
    if pplan.pp_impl != "masked":
        saved = (sum(x["head_gflops"] for x in reps["masked"]["stages"])
                 - sum(x["head_gflops"]
                       for x in reps[pplan.pp_impl]["stages"]))
        lines.append(f"reclaimed head+CE compute vs masked: {saved:.1f} GF "
                     f"per step ({pplan.pp - 1} of {pplan.pp} stages skip "
                     f"the vocab-sized matmul entirely)")
    text = "\n".join(lines)
    print(text)
    return text


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--parallel", default=None,
                    help="resolve a ParallelPlan spec (e.g. 'dp=2,pp=2,"
                         "ep=2') against --arch and print axes, per-param "
                         "placement and projected bytes/device; no compile")
    ap.add_argument("--analyze", action="store_true",
                    help="with --parallel: also lower+compile the reduced "
                         "train step and print the collective census and "
                         "sharding-contract verdicts (repro.analysis); "
                         "exits non-zero on a contract violation")
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--kernel-table", default=None,
                    help="tuning table for the per-kernel attribution's "
                         "measured columns: a path, 'none' (prediction "
                         "only), or omit for the committed default")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--opt-mode", default="epso", choices=["so", "epso", "none"])
    ap.add_argument("--sac", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--include-mula", action="store_true")
    ap.add_argument("--moe-opts", default=None,
                    help='JSON MoEConfig overrides, e.g. '
                         '\'{"etp_shard_map": true}\'')
    args = ap.parse_args()
    moe_opts = json.loads(args.moe_opts) if args.moe_opts else None

    if args.parallel:
        print_parallel_plan(args.parallel, args.arch or "mula-7b-a1b",
                            global_batch=args.global_batch,
                            kernel_table=args.kernel_table)
        if args.analyze:
            # Shardlint layer 1 on the same plan: census the lowered
            # reduced step and print per-contract verdicts. The module's
            # 512-device force (line 2) already covers any plan size.
            from repro.analysis import census as AC
            entry = AC.collect_plan_census(args.parallel,
                                           arch=args.arch or "mula-7b-a1b")
            print()
            print(AC.format_entry(entry))
            if entry["violations"]:
                sys.exit(1)
        return

    records, failures = [], []
    if args.all:
        archs = list(ASSIGNED_ARCHS)
        if args.include_mula:
            archs += [a for a in ARCH_REGISTRY if a.startswith("mula")]
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for arch, shape in combos(archs):
            for mp in meshes:
                try:
                    records.append(lower_one(arch, shape.name, multi_pod=mp,
                                             opt_mode=args.opt_mode,
                                             sac=args.sac,
                                             microbatches=args.microbatches,
                                             moe_opts=moe_opts))
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape.name, mp, repr(e)[:200]))
    else:
        records.append(lower_one(args.arch, args.shape,
                                 multi_pod=args.multi_pod,
                                 opt_mode=args.opt_mode, sac=args.sac,
                                 microbatches=args.microbatches,
                                 moe_opts=moe_opts))

    if args.out:
        with open(args.out, "w") as f:
            json.dump({"records": records, "failures": failures}, f, indent=1)
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print(f"dry-run ok: {len(records)} combination(s)")


if __name__ == "__main__":
    main()
