"""Probe-based roofline cost accounting (spec §ROOFLINE ANALYSIS).

XLA's ``cost_analysis()`` counts a ``while`` body once, so a scanned-layers
module under-reports FLOPs/bytes by ~the trip count. This module therefore
derives the roofline terms from *scan-free probes* — each structural
component is lowered and compiled on the real production mesh with its real
shardings, its HLO parsed exactly, and the totals composed with the known
structural trip counts:

    total = Σ_component  probe_cost(component) × trips(component)

Components per step kind:
  train    : per-layer fwd+bwd probe (with SAC remat, so recompute FLOPs are
             included) × L × microbatches; embed/head+CE probe × microbatches;
             optimizer-update probe × 1 (captures the paper's all-gather of
             updated params; the DP gradient reduce-scatter is added
             analytically per leaf — see _dp_grad_reduce_bytes).
  prefill  : per-layer fwd probe × L; embed/head fwd probe.
  decode   : per-layer decode probe × L; embed/head probe.

Probes run with ``layers.ATTN_BLOCK_OVERRIDE`` = full sequence, making the
flash-attention scans single-trip (FLOPs exact — the blockwise kernel
computes the same masked S² products). The memory term for attention is
corrected analytically: the probe materializes the (S×S) score tensor that
the real blockwise kernel keeps in VMEM, so we subtract the score traffic
and add the flash K/V re-read traffic (documented approximation; FLOPs and
collective terms are exact). Mamba recurrences get analytic scan-body
corrections (their in-scan flops are tiny relative to the matmuls).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, InputShape
from repro.models import model as M
from repro.models import layers as L
from repro.models import ssm as S
from repro.optim import adamw_init, adamw_update
from repro.optim.epso import optimizer_state_shardings
from repro.parallel.sharding import ShardingRules, shardings, param_specs
from repro.launch import roofline as RL


def _probe(fn, args, out_shardings=None):
    """Lower+compile a scan-free probe; return per-chip (flops, bytes, coll)."""
    jitted = jax.jit(fn, out_shardings=out_shardings) if out_shardings \
        else jax.jit(fn)
    compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # old jax: one dict per executable
        cost = cost[0] if cost else {}
    coll = RL.collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def _merge(acc, probe, mult=1.0):
    f, b, c = probe
    acc["flops"] += f * mult
    acc["bytes"] += b * mult
    for k, v in c.items():
        if k == "unknown_dtypes":      # list of dtype tokens, not a count
            cur = acc["coll"].get(k, [])
            acc["coll"][k] = sorted(set(cur) | set(v))
            continue
        acc["coll"][k] = acc["coll"].get(k, 0.0) + v * mult
    return acc


def _zero():
    return {"flops": 0.0, "bytes": 0.0, "coll": {}}


def _sds_tree(tree, shard_tree, mesh):
    if shard_tree is None:
        return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                            tree)
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shard_tree)


def _layer_params_shapes(cfg: ModelConfig, kind: str):
    """eval_shape one layer's params (unstacked)."""
    rng = jax.random.PRNGKey(0)
    if kind == "dense":
        return jax.eval_shape(lambda: M._init_dense_layer(rng, cfg))
    if kind == "moe":
        return jax.eval_shape(lambda: M._init_moe_layer(rng, cfg))
    if kind == "ssm":
        return jax.eval_shape(lambda: M._init_ssm_layer(rng, cfg))
    if kind == "xattn":
        return jax.eval_shape(lambda: M._init_xattn_layer(rng, cfg))
    raise ValueError(kind)


def _layer_shardings(cfg, lp_shapes, rules, prefix="layers"):
    """Reuse param_specs by faking the stacked path (specs are stack-aware,
    so wrap under the expected key with no leading dim shift needed)."""
    if rules.mesh is None:
        return None
    fake = {prefix: jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((1,) + l.shape, l.dtype), lp_shapes)}
    specs = param_specs(fake, rules)[prefix]
    # drop the leading stacked None entry
    def unstack(s, l):
        entries = list(s)[1:]
        return NamedSharding(rules.mesh, P(*entries))
    return jax.tree.map(unstack, specs, lp_shapes)


# ----------------------------------------------------------------------------
# attention memory-term corrections (analytic, per probe application)
# ----------------------------------------------------------------------------

def _flash_attn_bytes(cfg, rules, Bmb, Sq, Skv, *, train: bool) -> float:
    """Analytic per-chip HBM traffic of a blockwise (flash) attention — what
    a fused TPU kernel actually moves: Q/K/V/O streams + K/V re-reads per
    extra q-block. Replaces the probe's materialized-score traffic (an
    artifact of the probe's single-block XLA lowering)."""
    bshards = _tp_shards(rules)
    tp = 1
    if rules.mesh is not None and rules.tp_axis:
        n = rules.mesh.shape[rules.tp_axis]
        if cfg.num_heads % n == 0:
            tp = n
    B_loc = max(Bmb // max(bshards, 1), 1)
    nh_loc = cfg.num_heads // tp
    nkv_loc = max(cfg.num_kv_heads // tp, 1) if cfg.num_kv_heads else 1
    t = 2.0 * cfg.head_dim          # bf16 per (token, head)
    q = B_loc * Sq * nh_loc * t
    o = q
    k = B_loc * Skv * nkv_loc * t
    v = k
    nq = max(1, Sq // 512)
    base = q + k + v + o
    rereads = (nq - 1) * (k + v)
    if train:
        return 10.0 * base + 3.0 * rereads
    return base + rereads


def _ssm_scan_correction(cfg, B, Sq) -> tuple[float, float]:
    """(flops, bytes) under-counted by the recurrence scans (per layer)."""
    if cfg.ssm is None:
        return 0.0, 0.0
    # NOTE on bytes: the scan's stacked vjp-residual buffers live *outside*
    # the while loop, so the probe's "bytes accessed" already counts the
    # trajectory traffic; only the in-scan FLOPs are under-counted. The
    # per-step carry itself fits VMEM on the target (e.g. falcon-mamba:
    # B_loc*di*ds*4 = 8 MB < 16 MB v5e VMEM).
    if cfg.ssm.variant == "mamba1":
        di = cfg.ssm.expand * cfg.d_model
        ds = cfg.ssm.d_state
        body_f = 8.0 * B * di * ds   # decay+update+readout per step
        return body_f * (Sq - 1), 0.0
    d, di, H, Pd, N, _ = S.mamba2_dims(cfg)
    Lc = cfg.ssm.chunk
    C = max(1, Sq // Lc)
    body_f = 3.0 * B * Lc * H * Pd * N + 3.0 * B * H * Pd * N
    return body_f * (C - 1), 0.0


# ----------------------------------------------------------------------------
# per-arch structural decomposition
# ----------------------------------------------------------------------------

def _block_fn(cfg, kind, rules, mesh, sac):
    if kind == "dense":
        return lambda lp, x: M._dense_block(lp, x, cfg, rules, sac)
    if kind == "moe":
        return lambda lp, x: M._moe_block(lp, x, cfg, rules, sac, mesh)[0]
    if kind == "ssm":
        return lambda lp, x: M._ssm_block(lp, x, cfg, rules, sac)
    if kind == "xattn":
        mem_shape = None  # bound later
        raise RuntimeError("use _xattn_fn")
    raise ValueError(kind)


def structure(cfg: ModelConfig):
    """[(layer_kind, count)] per arch."""
    at = cfg.arch_type
    if at in ("dense", "vlm"):
        return [("dense", cfg.num_layers)]
    if at == "moe":
        return [("moe", cfg.num_layers)]
    if at == "ssm":
        return [("ssm", cfg.num_layers)]
    if at == "hybrid":
        n_shared = cfg.num_layers // cfg.shared_attn_every
        return [("ssm", cfg.num_layers), ("dense", n_shared)]
    if at == "audio":
        return [("enc", cfg.num_encoder_layers), ("xattn", cfg.num_layers)]
    raise ValueError(at)


def analyze(cfg: ModelConfig, shape: InputShape, rules: ShardingRules,
            *, opt_mode: str = "epso", sac: str = "block",
            microbatches: int = 1, compute_dtype=jnp.bfloat16) -> dict:
    """Compose probe costs into per-chip totals {flops, bytes, coll}."""
    mesh = rules.mesh
    chips = mesh.size if mesh else 1
    acc = _zero()
    B = shape.global_batch
    train = shape.kind == "train"
    nmb = microbatches if train else 1
    Bmb = max(B // nmb, 1)
    Sq = shape.seq_len
    if cfg.arch_type == "audio":
        Sq = shape.seq_len // 2
    if cfg.arch_type == "vlm":
        Sq = shape.seq_len

    bspec = P(rules.batch_axes if len(rules.batch_axes) != 1
              else rules.batch_axes[0], None, None) if mesh else None
    x_sds = (jax.ShapeDtypeStruct((Bmb, Sq, cfg.d_model), compute_dtype,
                                  sharding=NamedSharding(mesh, bspec))
             if mesh else
             jax.ShapeDtypeStruct((Bmb, Sq, cfg.d_model), compute_dtype))

    old_override = L.ATTN_BLOCK_OVERRIDE
    L.ATTN_BLOCK_OVERRIDE = max(Sq, 1)
    try:
        if shape.kind in ("train", "prefill"):
            _analyze_fwd(cfg, acc, rules, mesh, x_sds, Bmb, Sq, train, sac,
                         nmb, compute_dtype, shape)
        else:
            _analyze_decode(cfg, acc, rules, mesh, shape, compute_dtype)
    finally:
        L.ATTN_BLOCK_OVERRIDE = old_override

    if train:
        _analyze_optimizer(cfg, acc, rules, opt_mode)
    return {"flops_per_chip": acc["flops"], "bytes_per_chip": acc["bytes"],
            "coll_per_chip": acc["coll"], "chips": chips}


def _tp_shards(rules):
    if rules.mesh is None:
        return 1
    n = 1
    for a in rules.batch_axes:
        n *= rules.mesh.shape[a]
    return n


def _analyze_fwd(cfg, acc, rules, mesh, x_sds, Bmb, Sq, train, sac, nmb,
                 cd, shape):
    mult_batch_shards = _tp_shards(rules)

    def probe_block(kind, count, fn, extra_args=()):
        lp_shapes = _layer_params_shapes(
            cfg, "dense" if kind in ("enc", "dense") else kind)
        lsh = _layer_shardings(cfg, lp_shapes, rules)
        lp_sds = _sds_tree(lp_shapes, lsh, mesh)

        def wrap(f):
            body = f
            if train:
                body = M.block_remat(f, sac)  # count the SAC recompute
            if train:
                def loss_like(lp, x, *rest):
                    return (body(lp, x, *rest).astype(jnp.float32) ** 2).sum()
                return jax.grad(loss_like, argnums=(0, 1))
            return body

        pr = _probe(wrap(fn), (lp_sds, x_sds) + extra_args)
        _merge(acc, pr, count * nmb)

        # attention memory correction: swap the probe's materialized-score
        # traffic for the analytic flash-kernel traffic (FLOPs untouched)
        if kind in ("dense", "moe", "enc", "xattn") and cfg.num_heads:
            attn_pr = _probe(
                wrap(lambda lp, x: L.attention(
                    lp["attn"], x, cfg, constrain=rules.constrain,
                    causal=(kind != "enc"))), (lp_sds, x_sds))
            delta = _flash_attn_bytes(cfg, rules, Bmb, Sq, Sq,
                                      train=train) - attn_pr[1]
            if kind == "xattn":   # self + cross attention
                xpr = _probe(
                    wrap(lambda lp, x: L.attention(
                        lp["xattn"], x, cfg, constrain=rules.constrain,
                        memory=x)), (lp_sds, x_sds))
                delta += _flash_attn_bytes(cfg, rules, Bmb, Sq, Sq,
                                           train=train) - xpr[1]
            acc["bytes"] += delta * count * nmb

        # corrections for the recurrence scans (XLA counts bodies once)
        if kind == "ssm":
            cf, cb = _ssm_scan_correction(cfg, Bmb, Sq)
            f = (3.0 if train else 1.0)
            acc["flops"] += cf * f * count * nmb / mult_batch_shards
            acc["bytes"] += cb * f * count * nmb / mult_batch_shards

    for kind, count in structure(cfg):
        if kind == "dense":
            probe_block("dense", count,
                        lambda lp, x: M._dense_block(lp, x, cfg, rules, sac))
        elif kind == "enc":
            probe_block("enc", count,
                        lambda lp, x: M._dense_block(lp, x, cfg, rules, sac,
                                                     causal=False))
        elif kind == "moe":
            probe_block("moe", count,
                        lambda lp, x: M._moe_block(lp, x, cfg, rules, sac,
                                                   mesh)[0])
        elif kind == "ssm":
            probe_block("ssm", count,
                        lambda lp, x: M._ssm_block(lp, x, cfg, rules, sac))
        elif kind == "xattn":
            probe_block("xattn", count,
                        lambda lp, x, m: M._xattn_block(lp, x, m, cfg, rules,
                                                        sac),
                        extra_args=(x_sds,))

    # embed + head (+ CE loss when training)
    vp = M.padded_vocab(cfg)
    emb_shapes = jax.eval_shape(
        lambda: {"embed": L.init_embedding(jax.random.PRNGKey(0), vp,
                                           cfg.d_model),
                 "final_norm": L.init_norm(cfg.norm, cfg.d_model)})
    esh = shardings(emb_shapes, rules)
    emb_sds = _sds_tree(emb_shapes, esh, mesh)
    bspec1 = (NamedSharding(mesh, P(rules.batch_axes
                                    if len(rules.batch_axes) != 1
                                    else rules.batch_axes[0], None))
              if mesh else None)
    tok_sds = (jax.ShapeDtypeStruct((Bmb, Sq), jnp.int32, sharding=bspec1)
               if mesh else jax.ShapeDtypeStruct((Bmb, Sq), jnp.int32))

    def emb_head(p, tokens, h):
        e = L.embed(p["embed"], tokens, cd)
        hh = L.apply_norm(p["final_norm"], h + 0 * e, cfg.norm)
        logits = L.unembed(p["embed"], hh).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tokens[..., None], axis=-1)[..., 0]
        return (lse - ll).sum()

    if train:
        pr = _probe(jax.grad(emb_head, argnums=(0, 2)),
                    (emb_sds, tok_sds, x_sds))
    else:
        pr = _probe(emb_head, (emb_sds, tok_sds, x_sds))
    _merge(acc, pr, nmb)


def _analyze_decode(cfg, acc, rules, mesh, shape, cd):
    from repro.launch.specs import decode_input_specs
    B = shape.global_batch
    bspec = P(rules.batch_axes if len(rules.batch_axes) != 1
              else (rules.batch_axes[0] if rules.batch_axes else None),
              None, None)
    x_sds = (jax.ShapeDtypeStruct((B, 1, cfg.d_model), cd,
                                  sharding=NamedSharding(mesh, bspec))
             if mesh else jax.ShapeDtypeStruct((B, 1, cfg.d_model), cd))
    tokens, cache, index = decode_input_specs(cfg, shape, rules)

    def one_layer_cache(tree, kind="kv"):
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape[1:], l.dtype,
                sharding=NamedSharding(
                    mesh, P(*list(l.sharding.spec)[1:])) if mesh else None),
            tree)

    at = cfg.arch_type
    if at in ("dense", "vlm", "moe"):
        lp_shapes = _layer_params_shapes(cfg, "moe" if at == "moe" else "dense")
        lsh = _layer_shardings(cfg, lp_shapes, rules)
        lp_sds = _sds_tree(lp_shapes, lsh, mesh)
        kv = one_layer_cache(cache["kv"])

        def dec(lp, x, kv):
            a, kv2 = L.decode_attention(
                lp["attn"], L.apply_norm(lp["ln1"], x, cfg.norm), kv,
                jnp.int32(17), cfg)
            h = x + a
            x2 = L.apply_norm(lp["ln2"], h, cfg.norm)
            if at == "moe":
                from repro.core import moe as moe_lib
                mo, _, _, _ = moe_lib.sparse_moe_block(lp["moe"], x2, cfg,
                                                       mesh=None)
                return h + mo, kv2
            return h + L.apply_mlp(lp["mlp"], x2, cfg.mlp_activation), kv2

        _merge(acc, _probe(dec, (lp_sds, x_sds, kv)), cfg.num_layers)
    elif at == "ssm":
        lp_shapes = _layer_params_shapes(cfg, "ssm")
        lsh = _layer_shardings(cfg, lp_shapes, rules)
        lp_sds = _sds_tree(lp_shapes, lsh, mesh)
        c = one_layer_cache(cache["ssm"])
        stepf = (S.mamba1_decode_step if cfg.ssm.variant == "mamba1"
                 else S.mamba2_decode_step)

        def dec(lp, x, c):
            y, c2 = stepf(lp["mixer"], L.apply_norm(lp["ln"], x, cfg.norm),
                          c, cfg)
            return x + y, c2

        _merge(acc, _probe(dec, (lp_sds, x_sds, c)), cfg.num_layers)
    elif at == "hybrid":
        lp_shapes = _layer_params_shapes(cfg, "ssm")
        lsh = _layer_shardings(cfg, lp_shapes, rules)
        lp_sds = _sds_tree(lp_shapes, lsh, mesh)
        c = one_layer_cache(cache["groups"])

        def dec(lp, x, c):
            y, c2 = S.mamba2_decode_step(
                lp["mixer"], L.apply_norm(lp["ln"], x, cfg.norm), c, cfg)
            return x + y, c2

        _merge(acc, _probe(dec, (lp_sds, x_sds, c)), cfg.num_layers)
        # shared attention blocks
        sh_shapes = _layer_params_shapes(cfg, "dense")
        ssh = _layer_shardings(cfg, sh_shapes, rules)
        sh_sds = _sds_tree(sh_shapes, ssh, mesh)
        skv = one_layer_cache(cache["shared_kv"])

        def dec_sh(lp, x, kv):
            a, kv2 = L.decode_attention(
                lp["attn"], L.apply_norm(lp["ln1"], x, cfg.norm), kv,
                jnp.int32(17), cfg)
            h = x + a
            return h + L.apply_mlp(lp["mlp"],
                                   L.apply_norm(lp["ln2"], h, cfg.norm),
                                   cfg.mlp_activation), kv2

        _merge(acc, _probe(dec_sh, (sh_sds, x_sds, skv)),
               cfg.num_layers // cfg.shared_attn_every)
    elif at == "audio":
        lp_shapes = _layer_params_shapes(cfg, "xattn")
        lsh = _layer_shardings(cfg, lp_shapes, rules)
        lp_sds = _sds_tree(lp_shapes, lsh, mesh)
        kv = one_layer_cache(cache["kv"])
        mem = jax.ShapeDtypeStruct(
            cache["memory"].shape, cd,
            sharding=cache["memory"].sharding if mesh else None)

        def dec(lp, x, kv, mem):
            a, kv2 = L.decode_attention(
                lp["attn"], L.apply_norm(lp["ln1"], x, cfg.norm), kv,
                jnp.int32(17), cfg)
            h = x + a
            h = h + L.attention(lp["xattn"], L.apply_norm(lp["lnx"], h,
                                                          cfg.norm),
                                cfg, memory=mem)
            return h + L.apply_mlp(lp["mlp"],
                                   L.apply_norm(lp["ln2"], h, cfg.norm),
                                   cfg.mlp_activation), kv2

        _merge(acc, _probe(dec, (lp_sds, x_sds, kv, mem)), cfg.num_layers)

    # head
    vp = M.padded_vocab(cfg)
    emb_shapes = jax.eval_shape(
        lambda: {"embed": L.init_embedding(jax.random.PRNGKey(0), vp,
                                           cfg.d_model),
                 "final_norm": L.init_norm(cfg.norm, cfg.d_model)})
    esh = shardings(emb_shapes, rules)
    emb_sds = _sds_tree(emb_shapes, esh, mesh)

    def head(p, h):
        return L.unembed(L.apply_norm(p["final_norm"], h, cfg.norm),
                         p["embed"]) if False else \
            L.unembed(p["embed"], L.apply_norm(p["final_norm"], h, cfg.norm))

    _merge(acc, _probe(head, (emb_sds, x_sds)), 1)


def _dp_grad_reduce_bytes(params_shapes, rules) -> float:
    """Analytic per-device bytes for the DP gradient reduction (bf16,
    ring reduce-scatter): each leaf reduces over the batch axes it is
    replicated on."""
    if rules.mesh is None:
        return 0.0
    specs = param_specs(params_shapes, rules)
    total = 0.0
    for spec, leaf in zip(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
                          jax.tree.leaves(params_shapes)):
        used = set()
        for e in spec:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a is not None:
                    used.add(a)
        n = 1
        for a in rules.batch_axes:
            if a not in used:
                n *= rules.mesh.shape[a]
        if n > 1:
            shard = leaf.size
            for e in spec:
                for a in (e if isinstance(e, tuple) else (e,)):
                    if a is not None:
                        shard //= rules.mesh.shape[a]
            total += shard * 2.0 * (n - 1) / n    # bf16 reduction
    return total


def _analyze_optimizer(cfg, acc, rules, opt_mode):
    mesh = rules.mesh
    params_shapes = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    params_bf16 = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16), params_shapes)
    psh = shardings(params_bf16, rules)
    osh = optimizer_state_shardings(params_bf16, rules, opt_mode)
    opt_shapes = jax.eval_shape(adamw_init, params_bf16)

    def mk(tree, sh):
        return _sds_tree(tree, sh, mesh)

    grads = mk(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), params_bf16),
        psh)
    state = opt_shapes._replace(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        master=mk(opt_shapes.master, osh),
        m=mk(opt_shapes.m, osh),
        v=mk(opt_shapes.v, osh))

    def upd(grads, state):
        new_p, new_s, _ = adamw_update(grads, state, lr=1e-4,
                                       param_dtype=jnp.bfloat16)
        return new_p, new_s

    out_sh = (psh, state._replace(
        step=None, master=osh, m=osh, v=osh)) if mesh else None
    try:
        pr = _probe(upd, (grads, state), out_shardings=out_sh)
    except Exception:
        pr = _probe(upd, (grads, state))
    _merge(acc, pr, 1.0)
    acc["coll"]["dp-grad-reduce"] = acc["coll"].get("dp-grad-reduce", 0.0) + \
        _dp_grad_reduce_bytes(params_bf16, rules)
    acc["coll"]["total"] = sum(v for k, v in acc["coll"].items()
                               if k not in ("total", "unknown_dtypes"))


# ----------------------------------------------------------------------------
# analytic per-stage pipeline accounting (no compile — dryrun + bench_scaling)
# ----------------------------------------------------------------------------

def per_stage_costs(cfg: ModelConfig, *, pp: int, microbatches: int,
                    seq: int, global_batch: int,
                    pp_impl: str = "shardmap",
                    schedule: str = "1f1b") -> dict:
    """Projected per-stage FLOPs/bytes of one pipelined train step.

    Shape-only analytics (nothing is lowered or compiled): per-layer FLOPs
    come from the active per-layer parameter count plus the attention
    quadratic term; the embed/head/CE terms are attributed per stage
    according to the executor:

    * ``pp_impl='masked'`` — single-program SPMD: *every* stage pays the
      masked head+CE on every tick (fwd on F waves; recompute + backward on
      B waves) because SPMD cannot branch per stage.
    * ``pp_impl='shardmap'`` — per-stage programs: only stage 0 embeds,
      only the last stage runs head+CE, and the backward reuses the saved
      stage output (no head recompute on B waves).

    Both executors compute a masked F-wave and B-wave on every clock tick,
    so totals scale with the tick count T(n_mb, pp) — bubble ticks included
    (that is the honest simulated-mesh cost; on real stage-local hardware
    bubble ticks idle instead).

    Returns {"ticks", "stages": [{stage, role, block_gflops, embed_gflops,
    head_gflops, total_gflops, act_gbytes}, ...]}.
    """
    from repro.models.model import padded_vocab
    from repro.parallel.pipeline import schedule_masks

    n_mb = max(microbatches, 1)
    if pp > 1:
        T = schedule_masks(schedule, n_mb, pp)["ticks"]
    else:
        T = n_mb                                   # plain microbatch scan
    mb_rows = max(global_batch // n_mb, 1)
    t = mb_rows * seq                              # tokens per microbatch
    d = cfg.d_model
    vp = padded_vocab(cfg)

    # per-layer active params: active total minus embed/head tables
    emb_params = vp * d * (1 if cfg.tie_embeddings else 2)
    p_layer = max((cfg.active_param_count() - emb_params)
                  / max(cfg.num_layers, 1), 0.0)
    # fwd flops: 2*p*t matmuls + 4*t*S*d attention scores/values (causal
    # not discounted); one tick's work = 1x fwd (F wave) + 3x fwd-equiv
    # (B wave: block-input recompute + backward)
    f_layer = 2.0 * p_layer * t + 4.0 * t * seq * d
    f_head = 2.0 * t * d * vp                      # unembed matmul fwd
    layers_per_stage = max(cfg.num_layers // max(pp, 1), 1)

    stages = []
    for s in range(pp):
        first, last = s == 0, s == pp - 1
        block = T * 4.0 * f_layer * layers_per_stage
        if pp_impl == "masked" or pp == 1:
            head = T * 4.0 * f_head                # every stage, every tick
            embed_b = T * 2.0 * t * d * 4.0        # masked embed gather r/w
            role = "embed+blocks+head_ce (masked)" if pp > 1 else "all"
        else:
            head = T * 3.0 * f_head if last else 0.0   # saved-output bwd
            embed_b = T * 2.0 * t * d * 4.0 if first else 0.0
            role = ("embed+blocks" if first else
                    "blocks+head_ce" if last else "blocks")
        act_bytes = T * 2.0 * t * d * 4.0 + embed_b    # hand-off + embed
        stages.append({
            "stage": s, "role": role,
            "block_gflops": block / 1e9,
            "head_gflops": head / 1e9,
            "total_gflops": (block + head) / 1e9,
            "act_gbytes": act_bytes / 1e9,
        })
    return {"ticks": int(T), "pp": pp, "impl": pp_impl if pp > 1 else "-",
            "microbatches": n_mb, "stages": stages}


# ----------------------------------------------------------------------------
# analytic per-kernel attribution (no compile — dryrun --parallel)
# ----------------------------------------------------------------------------

def per_kernel_costs(cfg: ModelConfig, pplan, *, global_batch: int,
                     seq: int = 2048, hw: str | None = None,
                     table=None) -> dict:
    """Per-kernel roofline attribution of one MoE layer's forward pass,
    per device, under ``pplan``'s axis sizes. Shape-only analytics.

    Each row: analytic FLOPs/bytes (bf16 streams), arithmetic intensity,
    the ``hw`` roofline's predicted time and bound; plus — when the tuning
    ``table`` has a matching (kernel, backend, bucket) entry — the
    measured tiles/time and achieved-vs-peak fraction stamped at bench
    time. Predicted-vs-measured divergence per kernel is the number CI
    tracks (check_regression.py::check_kernels).
    """
    from repro.launch import roofline as RL

    spec = RL.get_hardware(hw or pplan.kernel.hw)
    moe = cfg.moe
    if moe is None:
        return {"hw": spec.name, "rows": [], "note": f"{cfg.name} has no "
                f"MoE block — per-kernel attribution covers expert kernels"}
    d = cfg.d_model
    f = moe.d_ff_expert
    E = moe.num_experts
    topk = moe.experts_per_token
    dp_ways = pplan.pod * pplan.dp * pplan.ep       # token rows shard here
    ep, tp = pplan.ep, pplan.tp
    t_loc = max(global_batch * seq // dp_ways, 1)   # tokens per device
    m = t_loc * topk                                # assigned rows/device
    g_loc = max(E // ep, 1)                         # experts per device
    f_loc = max(f // tp, 1) if f else f             # expert d_ff per device
    bb = 2.0                                        # bf16 stream bytes

    def row(kernel, dims, flops, byts):
        ai = flops / byts if byts else 0.0
        pred = spec.roofline_time(flops, byts)
        r = {"kernel": kernel, "dims": dims, "flops": flops, "bytes": byts,
             "ai": ai, "pred_ms": pred * 1e3,
             "bound": ("compute" if flops / spec.peak_flops
                       >= byts / spec.hbm_bw else "memory")}
        if table is not None:
            e = table.find(kernel, pplan.kernel.backend
                           if pplan.kernel.backend != "ref" else "pallas",
                           dims)
            if e is not None:
                r.update({"tiles": tuple(e["tiles"]),
                          "measured_ms": e["time_ms"],
                          "default_ms": e.get("default_time_ms"),
                          "measured_bucket": "_".join(
                              f"{k}{v}" for k, v in sorted(
                                  e["bucket"].items())),
                          "measured_hw": e.get("measured_hw", e.get("hw")),
                          "achieved_frac": e.get("achieved_frac")})
        return r

    rows = []
    # gate and up projections: one gmm each over the local expert stack
    gmm_b = bb * (m * d + g_loc * d * f_loc + m * f_loc)
    for name in ("gmm[gate]", "gmm[up]"):
        rows.append(row("gmm", {"g": g_loc, "m": m, "k": d, "n": f_loc},
                        2.0 * m * d * f_loc, gmm_b))
        rows[-1]["kernel_instance"] = name
    rows.append(row("gmm", {"g": g_loc, "m": m, "k": f_loc, "n": d},
                    2.0 * m * f_loc * d,
                    bb * (m * f_loc + g_loc * f_loc * d + m * d)))
    rows[-1]["kernel_instance"] = "gmm[down]"
    # fused SwiGLU: silu(gate) * up, ~5 flops/element in f32
    rows.append(row("fused_swiglu", {"m": m, "n": f_loc},
                    5.0 * m * f_loc, bb * 3.0 * m * f_loc))
    rows[-1]["kernel_instance"] = "fused_swiglu"
    # combine: weighted top-k reduction back to token order
    rows.append(row("combine", {"t": t_loc, "k": topk, "d": d},
                    2.0 * t_loc * topk * d,
                    bb * (t_loc * topk * d + t_loc * d) + 4.0 * t_loc * topk))
    rows[-1]["kernel_instance"] = "combine"
    # dispatch: histogram + gather into expert order (bandwidth only)
    rows.append(row("moe_dispatch", {"t": t_loc, "k": topk, "d": d},
                    0.0, bb * 2.0 * m * d))
    rows[-1]["kernel_instance"] = "moe_dispatch"
    if cfg.num_heads:
        nh_loc = max(cfg.num_heads // tp, 1)
        hd = cfg.head_dim
        rows.append(row("flash_attention",
                        {"t": t_loc, "s": seq, "h": nh_loc, "hd": hd},
                        4.0 * t_loc * seq * nh_loc * hd,
                        bb * 4.0 * t_loc * nh_loc * hd
                        + bb * 2.0 * t_loc * nh_loc * hd
                        * max(seq // 512 - 1, 0)))
        rows[-1]["kernel_instance"] = "flash_attention"
    return {"hw": spec.name, "per": "MoE layer fwd, per device",
            "tokens_per_device": t_loc, "rows": rows}
