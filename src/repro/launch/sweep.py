"""Crash-isolated dry-run sweep: one subprocess per (arch x shape x mesh)
combination, so an XLA fatal (F-check aborts the process, uncatchable in
Python) costs one combo, not the sweep. Merges per-combo JSONs.

    PYTHONPATH=src python -m repro.launch.sweep --out dryrun_results.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

from repro.configs import ARCH_REGISTRY, ASSIGNED_ARCHS, INPUT_SHAPES


def combos(archs):
    from repro.launch.dryrun import LONG_OK
    for a in archs:
        for s in INPUT_SHAPES.values():
            if s.name == "long_500k" and a not in LONG_OK:
                continue
            yield a, s.name


def run_one(arch, shape, multi_pod, extra, timeout):
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        out = f.name
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out] + extra
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    t0 = time.time()
    try:
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=timeout)
    except subprocess.TimeoutExpired:
        return None, f"timeout after {timeout}s"
    if r.returncode != 0:
        tail = (r.stderr or r.stdout).strip().splitlines()[-6:]
        return None, f"rc={r.returncode}: " + " | ".join(tail)
    try:
        with open(out) as f:
            rec = json.load(f)["records"][0]
        rec["wall_s"] = round(time.time() - t0, 1)
        return rec, None
    except Exception as e:
        return None, f"no record: {e}"
    finally:
        if os.path.exists(out):
            os.unlink(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--archs", nargs="*", default=None)
    ap.add_argument("--include-mula", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--timeout", type=int, default=1200)
    ap.add_argument("--extra", nargs="*", default=[])
    args = ap.parse_args()

    archs = args.archs or list(ASSIGNED_ARCHS)
    if args.include_mula:
        archs += [a for a in ARCH_REGISTRY if a.startswith("mula")]

    records, failures = [], []
    meshes = [False] if args.single_pod_only else [False, True]
    todo = [(a, s, mp) for a, s in combos(archs) for mp in meshes]
    for i, (a, s, mp) in enumerate(todo):
        tag = f"{a} x {s} @ {'2x16x16' if mp else '16x16'}"
        rec, err = run_one(a, s, mp, list(args.extra), args.timeout)
        if rec is None:
            failures.append({"arch": a, "shape": s, "multi_pod": mp,
                             "error": err})
            print(f"[{i+1}/{len(todo)}] FAIL {tag}: {err}", flush=True)
        else:
            records.append(rec)
            print(f"[{i+1}/{len(todo)}] ok   {tag} "
                  f"({rec['wall_s']}s, dominant={rec['dominant']})",
                  flush=True)
        with open(args.out, "w") as f:
            json.dump({"records": records, "failures": failures}, f, indent=1)
    print(f"done: {len(records)} ok, {len(failures)} failed -> {args.out}")


if __name__ == "__main__":
    main()
