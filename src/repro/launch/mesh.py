"""Production mesh construction (spec: MULTI-POD DRY-RUN step 1).

A function — not a module-level constant — so importing this module never
touches jax device state."""
from __future__ import annotations

import jax

from repro.compat import AxisType  # installs old-jax shims on import


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


# ---------------------------------------------------------------------------
# simulated multi-device CPU meshes (the --mesh launcher path)
# ---------------------------------------------------------------------------

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def parse_mesh_spec(spec):
    """``'8'`` -> (data,), ``'4,2'`` -> (data, model), ``'2,2,2'`` ->
    (data, pp, model) — the 3D training mesh: DP x pipeline stages x
    model(TP/EP) — and ``'2,2,2,2'`` -> (pod, data, pp, model).
    Returns (shape, axis_names)."""
    dims = tuple(int(x) for x in str(spec).split(",") if x.strip())
    if not 1 <= len(dims) <= 4 or any(d < 1 for d in dims):
        raise ValueError(f"bad mesh spec {spec!r} (want e.g. '8', '4,2', "
                         f"'2,2,2', '2,2,2,2')")
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("data", "pp", "model"),
            4: ("pod", "data", "pp", "model")}[len(dims)]
    return dims, axes


def ensure_host_devices(n: int) -> None:
    """Ask the CPU backend for ``n`` host devices by appending
    ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS. Only effective
    if the JAX backend has not initialized yet; respects a count the caller
    already set. Call before the first jax.devices()/PRNGKey in the process.
    """
    import os
    if n <= 1 or _FORCE_FLAG in os.environ.get("XLA_FLAGS", ""):
        return
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" {_FORCE_FLAG}={n}").strip()


def forced_device_env(n: int, env=None) -> dict:
    """Environment for a *child process* whose JAX backend should see ``n``
    CPU host devices. Respects a force-count the caller already set (same
    rule as ensure_host_devices). Used by the bench/test subprocess runners.
    """
    import os
    env = dict(os.environ if env is None else env)
    if _FORCE_FLAG not in env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" {_FORCE_FLAG}={n}").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def make_forced_mesh(shape, axes, *, what: str = None):
    """Mesh over forced CPU host devices — the one shared constructor behind
    the legacy ``--mesh`` path (make_sim_mesh) and ``ParallelPlan.resolve``,
    so the forced-device contract and its error message can never diverge
    between the two. Raises with the exact XLA_FLAGS fix if the backend
    came up with too few devices."""
    n = 1
    for d in shape:
        n *= d
    ensure_host_devices(n)
    ndev = len(jax.devices())
    if ndev < n:
        raise RuntimeError(
            f"{what or f'mesh {tuple(shape)}'} needs {n} devices but jax "
            f"sees {ndev}; the backend initialized before the mesh request "
            f"— launch with XLA_FLAGS='{_FORCE_FLAG}={n}' in the "
            f"environment")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def make_sim_mesh(spec):
    """Mesh from a CLI spec ('4,2') over forced CPU host devices."""
    shape, axes = parse_mesh_spec(spec)
    return make_forced_mesh(shape, axes, what=f"mesh {spec}")
