"""Production mesh construction (spec: MULTI-POD DRY-RUN step 1).

A function — not a module-level constant — so importing this module never
touches jax device state."""
from __future__ import annotations

import jax

from repro.compat import AxisType  # installs old-jax shims on import


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))
