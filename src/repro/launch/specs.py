"""ShapeDtypeStruct stand-ins for every model input/state (dry-run inputs:
weak-type-correct, shardable, zero device allocation).

``input_specs(cfg, shape, rules)`` — the training/prefill/serving batch.
``state_specs`` — a sharded TrainState skeleton via ``jax.eval_shape``.
``cache_specs`` — sharded KV/SSM cache skeleton for serve_step.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, InputShape, TrainConfig
from repro.models import init_cache
from repro.parallel.sharding import ShardingRules, shardings
from repro.optim.epso import optimizer_state_shardings
from repro.train.trainer import TrainState, init_state


def _sds(shape, dtype, rules: Optional[ShardingRules], spec: P):
    if rules is None or rules.mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(rules.mesh, spec))


def _batch_spec(rules, extra_dims: int) -> P:
    b = rules.batch_axes if rules else ()
    first = b if len(b) > 1 else (b[0] if b else None)
    return P(*([first] + [None] * extra_dims))


def input_specs(cfg: ModelConfig, shape: InputShape,
                rules: Optional[ShardingRules] = None) -> dict:
    """The batch pytree for the step this shape lowers (train / prefill)."""
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    bs1 = _batch_spec(rules, 1)
    bs2 = _batch_spec(rules, 2)
    if cfg.arch_type == "audio":
        # enc-dec: half the budget as encoder frames, half as decoder tokens
        half = S // 2
        return {"frame_embeds": _sds((B, half, cfg.d_model), jnp.bfloat16,
                                     rules, bs2),
                "tokens": _sds((B, half), tok, rules, bs1),
                "labels": _sds((B, half), tok, rules, bs1)}
    if cfg.arch_type == "vlm":
        text = S - cfg.num_prefix_embeds
        return {"tokens": _sds((B, text), tok, rules, bs1),
                "image_embeds": _sds((B, cfg.num_prefix_embeds, cfg.d_model),
                                     jnp.bfloat16, rules, bs2),
                "labels": _sds((B, text), tok, rules, bs1)}
    return {"tokens": _sds((B, S), tok, rules, bs1),
            "labels": _sds((B, S), tok, rules, bs1)}


def decode_input_specs(cfg: ModelConfig, shape: InputShape,
                       rules: Optional[ShardingRules] = None):
    """(tokens, cache, index) stand-ins for serve_step at this shape."""
    B, S = shape.global_batch, shape.seq_len
    tokens = _sds((B, 1), jnp.int32, rules, _batch_spec(rules, 1))
    cache_shapes = jax.eval_shape(
        lambda: init_cache(cfg, B, S, jnp.bfloat16))
    cache = jax.tree.map(
        lambda l, s: _sds(l.shape, l.dtype, rules, s),
        cache_shapes, cache_specs(cache_shapes, cfg, rules))
    index = jax.ShapeDtypeStruct((), jnp.int32)
    return tokens, cache, index


def cache_specs(cache_shapes, cfg: ModelConfig,
                rules: Optional[ShardingRules]):
    """PartitionSpec tree for a (layer-stacked) cache pytree."""
    if rules is None or rules.mesh is None:
        return jax.tree.map(lambda _: P(), cache_shapes)
    b = rules.batch_axes
    batch = b if len(b) > 1 else (b[0] if b else None)
    mdl = rules.tp_axis or rules.ep_axis

    def spec_for(path_parts, leaf):
        parts = [str(getattr(p, "key", getattr(p, "idx", p)))
                 for p in path_parts]
        last = parts[-1] if parts else ""
        path = "/".join(parts)
        shp = leaf.shape
        d = lambda i: mdl is not None and shp[i] % rules._axis_size(mdl) == 0
        if last in ("k", "v"):                            # (L,B,S,nkv,hd)
            return P(None, batch, None, mdl if d(3) else None, None)
        if "memory" in path:                              # (B,S,d)
            return P(batch, None, None)
        if last == "conv":                                # (L,B,K-1,C)
            return P(None, batch, None, mdl if d(3) else None)
        if last == "h":
            if len(shp) == 4:                             # mamba1 (L,B,di,ds)
                return P(None, batch, mdl if d(2) else None, None)
            return P(None, batch, mdl if d(2) else None, None, None)
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(spec_for, cache_shapes)


def state_specs(cfg: ModelConfig, train: TrainConfig, rules: ShardingRules,
                opt_mode: str = "epso"):
    """Sharded ShapeDtypeStruct TrainState (zero allocation). ``rules`` may
    be a ShardingRules or a resolved ParallelPlan (which also supplies the
    optimizer-sharding mode)."""
    if hasattr(rules, "rules"):          # a ResolvedPlan
        opt_mode = rules.opt_shard
        rules = rules.rules
    shapes = jax.eval_shape(
        lambda: init_state(jax.random.PRNGKey(0), cfg, train))
    pshard = shardings(shapes.params, rules)
    oshard = optimizer_state_shardings(shapes.params, rules, opt_mode)
    if pshard is None:
        return shapes

    def mk(leaf, sh):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=sh)

    params = jax.tree.map(mk, shapes.params, pshard)
    rep = NamedSharding(rules.mesh, P())
    opt = shapes.opt._replace(
        step=mk(shapes.opt.step, rep),
        master=jax.tree.map(mk, shapes.opt.master, oshard),
        m=jax.tree.map(mk, shapes.opt.m, oshard),
        v=jax.tree.map(mk, shapes.opt.v, oshard))
    return TrainState(params, opt)
