"""FastSparseMoE — the paper's §3.1 five-stage MoE block, adapted to TPU.

Three execution paths (DESIGN §4), all computing the same math:

* ``naive``          — HF-OLMoE-equivalent baseline: every expert processes
                       every token, one-hot combine. O(E/K) extra compute.
* ``dense_capacity`` — sort-based dispatch into a shared capacity pool,
                       grouped expert compute. Pure XLA, auto-shardable.
* ``fsmoe``          — the paper-faithful five-stage pipeline under EP:
      Stage 1  token communication: all_gather(x, weights, indices) over the
               EP mesh axis (paper: allgather beats all2all thanks to the
               regular communication pattern); its backward is the paper's
               reduce-scatter.
      Stage 2  token counting: per-local-expert histogram (Pallas kernel or
               XLA bincount).
      Stage 3  index generation: argsort of the flattened local expert ids
               reproduces the paper's (input_indices, output_indices) with
               static shapes — the TPU adaptation of the atomic-counter GPU
               kernels (DESIGN §3).
      Stage 4  expert computation: merged expert weights + grouped matmul
               over a ragged-aligned slot pool (Pallas gmm, or an
               expert-masked batched contraction on the XLA path).
      Stage 5  output reduction: weighted combine of the K expert rows per
               token (Pallas combine kernel or XLA einsum), then
               psum_scatter over the EP axis.

Dispatch modes (``MoEConfig.dispatch``): routed-token buffers are always
static.

* ``capacity`` — ``capacity_factor`` sizes a shared slot pool; per-expert
  group offsets are count-aligned, so imbalance is absorbed by the pool
  rather than per-expert truncation; tokens past the pool are dropped.
  cf >= E/K guarantees zero drops; FUR is dropless at cf >= 1.
* ``dropless`` — the pool is sized for the worst-case routing
  (``dropless_pool_rows``: all T*K pairs to one expert still fit), groups
  are always count-aligned ragged (the grouped-matmul layout), and the
  result is exactly the naive math for ANY routing — independent of
  capacity_factor and of pool-geometry knobs like ``c_align``, which is
  what makes pp=1 and pp>1 losses bit-comparable at any batch shape.

Every path reports ``MoeStats`` (per-expert activation counts + drop
count) so the train step can surface routing telemetry.

Expert placement (``parallel/placement.py``): every path takes an
optional ``placement`` — the (E,) *inverse* permutation row mapping
global expert id -> placed position. The stacked expert weights are
stored in placed order (position p holds global expert perm[p]), the
router keeps producing global ids, and dispatch translates
``indices -> placement[indices]`` so each token reaches the position
hosting its expert; reported ``MoeStats.counts`` are translated back
(``counts_pos[placement]``) so telemetry stays in global expert order.
Router weights and shared experts are never permuted.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro import compat as _compat  # noqa: F401 — installs jax.shard_map on old jax

from .router import RouterOut, route


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def stage45_backend(moe_cfg) -> str:
    """Stage-4/5 (grouped FFN + combine) backend. The active KernelPlan
    wins when it names a concrete backend ('xla' | 'pallas'); under its
    'ref' default the per-config ``kernel_backend`` knob decides — so a
    plan can retarget the kernels without touching the model config."""
    from repro.parallel.plan import current_kernel_plan
    kp = current_kernel_plan()
    if kp.backend != "ref":
        return kp.moe_backend
    return moe_cfg.kernel_backend


# ----------------------------------------------------------------------------
# params
# ----------------------------------------------------------------------------

def init_moe_block(rng, cfg) -> dict:
    """Stacked (merged) expert weights — paper Stage 4 merges per-rank expert
    weights into single tensors to enable grouped GEMM."""
    d, m = cfg.d_model, cfg.moe
    e, f = m.num_experts, m.d_ff_expert
    ks = jax.random.split(rng, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in,
        "gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * s_in,
        "up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * s_in,
        "down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * s_out,
    }
    if m.num_shared_experts:
        fs = f * m.num_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": jax.random.normal(kss[0], (d, fs), jnp.float32) * s_in,
            "up": jax.random.normal(kss[1], (d, fs), jnp.float32) * s_in,
            "down": jax.random.normal(kss[2], (fs, d), jnp.float32) * s_out,
        }
    return p


def _shared_expert(p, x):
    sp = p["shared"]
    h = jax.nn.silu(x @ sp["gate"].astype(x.dtype)) * (x @ sp["up"].astype(x.dtype))
    return h @ sp["down"].astype(x.dtype)


# ----------------------------------------------------------------------------
# naive baseline (HF-style: all experts compute all tokens)
# ----------------------------------------------------------------------------

def moe_naive(p, x, moe_cfg, placement=None) -> tuple[jax.Array, RouterOut]:
    r = route(x, p["router"], num_experts=moe_cfg.num_experts,
              top_k=moe_cfg.experts_per_token,
              forced_uniform=moe_cfg.forced_uniform_routing)

    def one(gate, up, down):
        h = jax.nn.silu(x @ gate) * (x @ up)
        return h @ down

    ys = jax.vmap(one)(p["gate"].astype(x.dtype), p["up"].astype(x.dtype),
                       p["down"].astype(x.dtype))           # (E, T, d)
    # combine indexes stored (placed) positions; r keeps global ids
    idx = r.indices if placement is None else placement[r.indices]
    one_hot = jax.nn.one_hot(idx, moe_cfg.num_experts, dtype=x.dtype)
    cw = (one_hot * r.weights[..., None].astype(x.dtype)).sum(1)  # (T, E)
    out = jnp.einsum("te,etd->td", cw, ys)
    if moe_cfg.num_shared_experts:
        out = out + _shared_expert(p, x)
    return out, r


# ----------------------------------------------------------------------------
# Stages 2+3: token counting + sort-based index generation
# ----------------------------------------------------------------------------

class DispatchPlan(NamedTuple):
    slot: jax.Array          # (T*K,) destination row in the slot pool (OOB=pool_rows)
    valid: jax.Array         # (T*K,) bool — False = dropped or non-local
    counts: jax.Array        # (EL,) exact tokens routed per local expert
    group_sizes: jax.Array   # (EL,) aligned slot-pool group sizes
    pool_rows: int           # static slot-pool size
    drops: jax.Array         # scalar: number of dropped (over-capacity) pairs


class MoeStats(NamedTuple):
    """Per-layer routing telemetry. float32 (not int) so it rides through
    vjp/scan/psum alongside the loss scalars with zero cotangents."""
    counts: jax.Array        # (E,) routed (t, k) pairs per global expert
    drops: jax.Array         # () pairs dropped over capacity (0 when dropless)

    @classmethod
    def zero(cls, num_experts: int) -> "MoeStats":
        return cls(jnp.zeros((num_experts,), jnp.float32),
                   jnp.zeros((), jnp.float32))

    def __add__(self, other: "MoeStats") -> "MoeStats":
        return MoeStats(self.counts + other.counts, self.drops + other.drops)


def make_dispatch_plan(indices: jax.Array, *, num_experts: int,
                       pool_rows: int, align: int = 8,
                       expert_offset=0, local_experts: int = 0,
                       uniform_capacity: bool = False) -> DispatchPlan:
    """Sort-based index generation (paper Stage 3, DESIGN §3).

    indices: (T, K) global expert ids. When ``local_experts`` > 0, only
    experts in [expert_offset, expert_offset + local_experts) are dispatched
    (the EP case); others sort to the sentinel end and are masked out.
    ``expert_offset`` may be a traced scalar (lax.axis_index under EP).

    ``uniform_capacity``: every expert gets exactly pool_rows/EL slots
    (GShard-style — the XLA backend reshapes the pool to (EL, C, d) for a
    batched einsum). False: count-aligned ragged offsets sharing the pool
    (the Pallas gmm backend's group-aligned layout — absorbs imbalance).
    """
    T, K = indices.shape
    EL = local_experts or num_experts
    flat = indices.reshape(-1).astype(jnp.int32) - expert_offset
    local = (flat >= 0) & (flat < EL)
    key = jnp.where(local, flat, EL).astype(jnp.int32)    # non-local -> sentinel
    order = jnp.argsort(key, stable=True)                 # (T*K,)
    sorted_key = key[order]

    counts_all = jnp.bincount(key, length=EL + 1)         # Stage 2 histogram
    counts = counts_all[:EL].astype(jnp.int32)
    if uniform_capacity:
        cap = pool_rows // EL
        group_sizes = jnp.full((EL,), cap, jnp.int32)
        offsets = (jnp.arange(EL + 1) * cap).astype(jnp.int32)
    else:
        gs_aligned = ((counts + align - 1) // align) * align
        cum = jnp.minimum(jnp.cumsum(gs_aligned), pool_rows)
        offsets = jnp.concatenate([jnp.zeros((1,), cum.dtype), cum])  # (EL+1,)
        group_sizes = (offsets[1:] - offsets[:-1]).astype(jnp.int32)

    # position of each sorted element within its expert group
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(counts_all)[:-1].astype(jnp.int32)])             # (EL+1,)
    pos_sorted = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_key]

    safe_key = jnp.minimum(sorted_key, EL - 1)
    slot_sorted = offsets[safe_key].astype(jnp.int32) + pos_sorted
    valid_sorted = (sorted_key < EL) & (pos_sorted < group_sizes[safe_key])
    slot_sorted = jnp.where(valid_sorted, slot_sorted, pool_rows)    # OOB

    slot = jnp.zeros((T * K,), jnp.int32).at[order].set(slot_sorted)
    valid = jnp.zeros((T * K,), bool).at[order].set(valid_sorted)
    drops = jnp.sum(local) - jnp.sum(valid_sorted)
    return DispatchPlan(slot, valid, counts, group_sizes, int(pool_rows), drops)


def pool_size(tokens: int, top_k: int, num_experts: int, local_experts: int,
              capacity_factor: float, align: int = 8) -> int:
    """Static slot-pool rows for one EP shard."""
    expected = tokens * top_k * local_experts / num_experts
    return round_up(int(math.ceil(capacity_factor * expected)) + align *
                    local_experts, align)


def dropless_pool_rows(tokens: int, top_k: int, local_experts: int,
                       align: int = 8) -> int:
    """Slot-pool rows guaranteeing zero drops for ANY routing: even if one
    expert receives every local (t, k) pair its aligned group still fits,
    and the ``align * EL`` slack absorbs per-group alignment padding
    (each group rounds up by < align rows)."""
    return round_up(tokens * top_k, align) + align * local_experts


# ----------------------------------------------------------------------------
# Stage 4: grouped expert FFN — XLA and Pallas backends
# ----------------------------------------------------------------------------

def grouped_ffn(gate_w, up_w, down_w, pool_x, group_sizes, backend: str,
                constrain=None):
    """pool_x: (M, d) rows grouped by expert; w: (EL, d, f)/(EL, f, d).

    backend 'pallas': ragged grouped-matmul kernels (paper Stage 4).
    backend 'xla'   : uniform-capacity batched einsum (GShard-style) —
                      reshape (EL, C, d); exact-FLOP XLA lowering.
    backend 'ragged': count-ragged groups via an expert-masked batched
                      contraction (costs EL dense matmuls, same as XLA's
                      CPU lowering of lax.ragged_dot).
    """
    cons = constrain or (lambda x, n: x)
    if backend == "pallas":
        from repro.kernels.ops import gmm, fused_swiglu
        g = gmm(pool_x, gate_w.astype(pool_x.dtype), group_sizes)
        u = gmm(pool_x, up_w.astype(pool_x.dtype), group_sizes)
        h = fused_swiglu(g, u)
        h = checkpoint_name(h, "moe_hidden")
        return gmm(h, down_w.astype(pool_x.dtype), group_sizes)
    if backend == "ragged":
        # NOT lax.ragged_dot: XLA's SPMD partitioner rewrites ragged_dot's
        # group_sizes operand into per-shard windows when the expert dim is
        # sharded, and the rewritten values leak into every OTHER consumer
        # of group_sizes (negative sizes -> phantom drops, diverged loss on
        # any mesh with an ep/tp axis). A 0/1 expert mask partitions like
        # any einsum and adds exact zeros, so the values are unchanged.
        EL = gate_w.shape[0]
        ends = jnp.cumsum(group_sizes)
        e_row = jnp.searchsorted(ends, jnp.arange(pool_x.shape[0]),
                                 side="right")          # slack rows -> EL
        oh = jax.nn.one_hot(e_row, EL, dtype=pool_x.dtype)      # (M, EL)

        def masked(h, w, sub):                          # h:(M,a) w:(EL,a,b)
            return jnp.einsum(f"em{sub[-1]},me->m{sub[-1]}",
                              jnp.einsum(f"m{sub[0]},e{sub}->em{sub[-1]}",
                                         h, w.astype(pool_x.dtype)), oh)

        g = masked(pool_x, gate_w, "df")
        u = masked(pool_x, up_w, "df")
        h = jax.nn.silu(g) * u
        h = checkpoint_name(h, "moe_hidden")
        return masked(h, down_w, "fd")
    # 'xla': uniform capacity — (EL, C, d) batched matmul
    EL = gate_w.shape[0]
    M, d = pool_x.shape
    C = M // EL
    xb = cons(pool_x.reshape(EL, C, d), "moe_pool")
    g = jnp.einsum("ecd,edf->ecf", xb, gate_w.astype(pool_x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xb, up_w.astype(pool_x.dtype))
    h = cons(jax.nn.silu(g) * u, "moe_hidden")
    h = checkpoint_name(h, "moe_hidden")
    out = jnp.einsum("ecf,efd->ecd", h, down_w.astype(pool_x.dtype))
    return out.reshape(M, d)


# ----------------------------------------------------------------------------
# Stages 2-5 on one shard
# ----------------------------------------------------------------------------

def dispatch_compute_combine(gate_w, up_w, down_w, x, r: RouterOut, moe_cfg,
                             *, expert_offset=0, local_experts: int = 0,
                             backend: str = "xla", constrain=None,
                             c_align: int = 1, pool_rows=None,
                             dropless: bool = False):
    """x: (T, d) tokens (already gathered under EP); expert weights are the
    *local* slices (EL experts). Returns (partial out (T, d), plan).

    ``c_align``: make the per-expert capacity C divisible by this (the
    batch-shard count, so the (EL, C, d) pool can shard its C dim).
    ``pool_rows``: explicit slot-pool size (a2a path supplies its own).
    ``dropless``: size the pool for the worst-case routing and use the
    count-aligned ragged layout — no drops, and the pool geometry knobs
    (capacity_factor, c_align, pool_rows) are ignored, so the result is
    naive-exact regardless of executor."""
    T, d = x.shape
    K = moe_cfg.experts_per_token
    E = moe_cfg.num_experts
    EL = local_experts or E
    align = 8
    if backend == "pallas":
        from repro.kernels.ops import gmm_align
        align = gmm_align()   # Pallas gmm needs tile_m-aligned groups
    if dropless:
        # worst-case pool; the uniform-capacity (EL, C, d) reshape cannot be
        # statically dropless, so the XLA backend computes through the
        # ragged (expert-masked) grouped matmul
        rows = dropless_pool_rows(T, K, EL, align=align)
        uniform = False
    else:
        rows = pool_rows if pool_rows is not None else \
            pool_size(T, K, E, EL, moe_cfg.capacity_factor, align=align)
        rows = round_up(rows, EL * align * max(c_align, 1))  # EL uniform groups
        uniform = backend == "xla"
    plan = make_dispatch_plan(r.indices, num_experts=E, pool_rows=rows,
                              expert_offset=expert_offset, local_experts=EL,
                              align=align, uniform_capacity=uniform)
    if dropless and backend == "xla":
        backend = "ragged"
    if backend == "pallas":
        # Stage 2 on the Pallas path: histogram computed in-kernel; checked
        # against the plan's bincount by tests. (Same values; plan drives
        # index generation either way.)
        pass

    # inverse map: pool row -> source token (paper: mlp_in = input[input_indices])
    tok_flat = jnp.arange(T * K, dtype=jnp.int32) // K
    inv_token = jnp.zeros((rows,), jnp.int32).at[plan.slot].set(
        tok_flat, mode="drop")
    pool_valid = jnp.zeros((rows,), bool).at[plan.slot].set(
        plan.valid, mode="drop")
    pool_x = x[inv_token] * pool_valid[:, None].astype(x.dtype)
    pool_x = checkpoint_name(pool_x, "moe_dispatch")

    pool_y = grouped_ffn(gate_w, up_w, down_w, pool_x, plan.group_sizes,
                         backend, constrain=constrain)

    # ---- Stage 5: weighted combine --------------------------------------
    safe_slot = jnp.minimum(plan.slot, rows - 1)
    yk = pool_y[safe_slot] * plan.valid[:, None].astype(pool_y.dtype)
    yk = yk.reshape(T, K, d)
    if backend == "pallas":
        from repro.kernels.ops import combine as combine_kernel
        out = combine_kernel(yk, r.weights.astype(pool_y.dtype))
    else:
        out = jnp.einsum("tkd,tk->td", yk, r.weights.astype(yk.dtype))
    return out, plan


# ----------------------------------------------------------------------------
# dense_capacity (no EP shard_map; pjit auto-shards)
# ----------------------------------------------------------------------------

def _moe_dense(p, x, moe_cfg, *, backend: str, constrain=None,
               c_align: int = 1, dropless: bool = False, placement=None):
    """Shared core of the auto-sharded (no shard_map) paths. Returns
    (out, router_out, MoeStats)."""
    r = route(x, p["router"], num_experts=moe_cfg.num_experts,
              top_k=moe_cfg.experts_per_token,
              forced_uniform=moe_cfg.forced_uniform_routing)
    rd = r if placement is None else \
        RouterOut(r.weights, placement[r.indices], r.aux_loss, r.z_loss)
    out, plan = dispatch_compute_combine(p["gate"], p["up"], p["down"], x, rd,
                                         moe_cfg, backend=backend,
                                         constrain=constrain, c_align=c_align,
                                         dropless=dropless)
    if moe_cfg.num_shared_experts:
        out = out + _shared_expert(p, x)
    counts = plan.counts if placement is None else plan.counts[placement]
    stats = MoeStats(counts.astype(jnp.float32),
                     plan.drops.astype(jnp.float32))
    return out, r, stats


def moe_dense_capacity(p, x, moe_cfg, backend: str = "xla", constrain=None,
                       c_align: int = 1):
    out, r, _ = _moe_dense(p, x, moe_cfg, backend=backend,
                           constrain=constrain, c_align=c_align)
    return out, r


def moe_dropless(p, x, moe_cfg, backend: str = "xla", constrain=None,
                 placement=None):
    """Dropless dispatch (tentpole): true per-expert counts feed the grouped
    matmul's ragged ``group_sizes`` and the worst-case pool guarantees
    stats.drops == 0 for any routing. Returns (out, router_out, MoeStats)."""
    return _moe_dense(p, x, moe_cfg, backend=backend, constrain=constrain,
                      dropless=True, placement=placement)


# ----------------------------------------------------------------------------
# fsmoe under EP: the five-stage pipeline inside shard_map
# ----------------------------------------------------------------------------

def _fsmoe_stats(plan_counts, drops, *, ep_axis, batch_axes, manual,
                 extra_drops=None):
    """Global MoeStats from one EP rank's dispatch plan.

    counts: each rank holds its (EL,) local-expert histogram over the
    ep-gathered tokens — all_gather over ep concatenates them into the
    global (E,) vector (rank order == expert order), then token-partitioning
    axes (batch) psum and token-replicating axes (expert-TP) pmean.
    drops: psum over ep (each rank drops its own experts' overflow) and over
    batch axes; pmean over replicating axes — NOT psum over everything,
    which would multiply-count drops under expert-TP."""
    counts = jax.lax.all_gather(plan_counts.astype(jnp.float32), ep_axis,
                                tiled=True)
    drops = drops.astype(jnp.float32)
    if extra_drops is not None:
        drops = drops + extra_drops.astype(jnp.float32)
    drops = jax.lax.psum(drops, ep_axis)
    for ax in manual:
        if ax == ep_axis:
            continue
        if ax in batch_axes:
            counts = jax.lax.psum(counts, ax)
            drops = jax.lax.psum(drops, ax)
        else:
            counts = jax.lax.pmean(counts, ax)
            drops = jax.lax.pmean(drops, ax)
    return MoeStats(counts, drops)


def moe_fsmoe_ep(p, x, moe_cfg, *, mesh, ep_axis: str = "model",
                 batch_axes=("data",), tp_axis=None, dropless: bool = False,
                 placement=None):
    """Paper Algorithm 1 under EP. Tokens x: (N, d) sharded over
    (batch_axes..., ep_axis) on dim 0; expert weights sharded over ep_axis on
    the stacked expert dim. The body is fully manual so the dispatch sort
    stays local to each (pod, data) group (no cross-DP communication).

    ``tp_axis`` composes expert-TP on top of EP (the ParallelPlan ep x tp
    mesh): each expert's d_ff is additionally sharded over ``tp_axis``
    (gate/up column-sharded, down row-sharded), every tp rank runs the same
    dispatch on replicated tokens, and the partial expert outputs are
    psum'd over ``tp_axis`` before the Stage-5 reduce-scatter — one extra
    all-reduce per MoE layer, like a Megatron MLP.
    """
    from jax.sharding import PartitionSpec as P

    E = moe_cfg.num_experts
    ep = mesh.shape[ep_axis]
    assert E % ep == 0, f"{E} experts not divisible by EP={ep}"
    EL = E // ep
    if tp_axis is not None and tp_axis not in mesh.shape:
        raise ValueError(
            f"tp_axis {tp_axis!r} is not a mesh axis "
            f"(mesh has {tuple(mesh.shape)}): expert-TP needs a real axis — "
            f"drop tp_axis for plain EP, or add the axis to the plan")
    if tp_axis is not None and moe_cfg.d_ff_expert % mesh.shape[tp_axis]:
        raise ValueError(
            f"expert d_ff={moe_cfg.d_ff_expert} not divisible by "
            f"tp={mesh.shape[tp_axis]} (axis {tp_axis!r})")
    if dropless and moe_cfg.stage1 == "a2a":
        raise ValueError(
            "dispatch='dropless' does not compose with stage1='a2a': the "
            "all-to-all send buffers are capacity-bounded by construction. "
            "Use the allgather Stage 1 (stage1='allgather') for dropless.")
    # manual over ALL mesh axes: leaving an axis (e.g. 'pod') auto at the
    # shard_map boundary trips an XLA SPMD repartitioning bug ("Invalid
    # binary instruction opcode copy") on multi-pod meshes.
    manual = set(mesh.shape.keys())
    batch_axes = tuple(a for a in batch_axes if a in mesh.shape)
    token_spec = P(tuple(batch_axes) + (ep_axis,), None)

    def body(router_w, gate, up, down, xl, pl=None):
        if moe_cfg.stage1 == "a2a":
            if tp_axis is not None:
                raise NotImplementedError(
                    "stage1='a2a' does not compose with expert-TP yet; use "
                    "the allgather Stage 1 for ep x tp plans")
            return _fsmoe_a2a_body(gate, up, down, router_w, xl, moe_cfg,
                                   ep_axis=ep_axis, ep=ep, manual=manual,
                                   batch_axes=batch_axes, placement=pl)
        # Router on local tokens (router replicated — paper §3.1).
        r = route(xl, router_w, num_experts=E,
                  top_k=moe_cfg.experts_per_token,
                  forced_uniform=moe_cfg.forced_uniform_routing)
        # placed-order dispatch: global ids -> stored positions (aux/z losses
        # already computed on global ids inside route)
        idx = r.indices if pl is None else pl[r.indices]
        # ---- Stage 1: allgather tokens + routing over the EP axis -------
        x_g = jax.lax.all_gather(xl, ep_axis, tiled=True)
        w_g = jax.lax.all_gather(r.weights, ep_axis, tiled=True)
        i_g = jax.lax.all_gather(idx, ep_axis, tiled=True)
        r_g = RouterOut(w_g, i_g, r.aux_loss, r.z_loss)
        # ---- Stages 2-5 on the local expert (and d_ff) slice -------------
        rank = jax.lax.axis_index(ep_axis)
        out_partial, plan = dispatch_compute_combine(
            gate, up, down, x_g, r_g, moe_cfg,
            expert_offset=rank * EL, local_experts=EL,
            backend=stage45_backend(moe_cfg), dropless=dropless)
        if tp_axis is not None:
            # expert-TP: sum the per-d_ff-shard partial outputs (the combine
            # is linear in the expert rows, so summing after it is exact)
            out_partial = jax.lax.psum(out_partial, tp_axis)
        # ---- Stage 5 tail: reduce-scatter to local tokens ----------------
        out_local = jax.lax.psum_scatter(out_partial, ep_axis,
                                         scatter_dimension=0, tiled=True)
        aux = r.aux_loss
        z = r.z_loss
        for ax in manual:
            aux = jax.lax.pmean(aux, ax)
            z = jax.lax.pmean(z, ax)
        stats = _fsmoe_stats(plan.counts, plan.drops, ep_axis=ep_axis,
                             batch_axes=batch_axes, manual=manual)
        if pl is not None:     # report counts back in global expert order
            stats = MoeStats(stats.counts[pl], stats.drops)
        return out_local, aux, z, stats

    operands = [p["router"], p["gate"], p["up"], p["down"], x]
    in_specs = [P(), P(ep_axis, None, tp_axis), P(ep_axis, None, tp_axis),
                P(ep_axis, tp_axis, None), token_spec]
    if placement is not None:
        operands.append(jnp.asarray(placement, jnp.int32))
        in_specs.append(P(None))
    out, aux, z, stats = jax.shard_map(
        body, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(token_spec, P(), P(), MoeStats(P(None), P())),
        axis_names=manual)(*operands)
    out = checkpoint_name(out, "moe_out")
    if moe_cfg.num_shared_experts:
        out = out + _shared_expert(p, x)
    return out, RouterOut(None, None, aux, z), stats


# ----------------------------------------------------------------------------
# beyond-paper: Stage-1 all-to-all dispatch variant
# ----------------------------------------------------------------------------

def _fsmoe_a2a_body(gate, up, down, router_w, xl, moe_cfg, *, ep_axis, ep,
                    manual, batch_axes=(), placement=None):
    """Capacity-bounded all-to-all dispatch (EXPERIMENTS §Perf, dbrx
    hillclimb). The paper sends *all* tokens to *all* EP ranks (allgather,
    chosen because oneCCL's allgather beats its irregular all-to-all). On
    TPU ICI the bytes roofline favors sending each token only to the ranks
    owning its K chosen experts: per-chip traffic drops from (EP-1)/EP·T·d
    to ~cf·K/EP·T·d each way.

    Pipeline: local route -> sort tokens by destination rank into uniform
    (EP, Cd) send buffers -> all_to_all -> local Stage 2/3 dispatch of the
    received rows among the EL local experts (each row is a single (t,k)
    pair, so K'=1) -> Stage 4 grouped FFN + Stage 5 weighting -> reverse
    all_to_all -> per-token sum over the K slots at the source."""
    E = moe_cfg.num_experts
    EL = E // ep
    K = moe_cfg.experts_per_token
    T_loc, d = xl.shape

    r = route(xl, router_w, num_experts=E, top_k=K,
              forced_uniform=moe_cfg.forced_uniform_routing)
    # placed-order dispatch: translate global ids to stored positions
    idx = r.indices if placement is None else placement[r.indices]

    # --- build per-destination send buffers (dest rank = expert // EL) ----
    dest = (idx // EL).astype(jnp.int32)                     # (T,K)
    Cd = round_up(int(math.ceil(moe_cfg.capacity_factor * T_loc * K / ep)), 8)
    plan = make_dispatch_plan(dest, num_experts=ep, pool_rows=ep * Cd,
                              uniform_capacity=True)
    tok_flat = jnp.arange(T_loc * K, dtype=jnp.int32) // K
    inv_tok = jnp.zeros((ep * Cd,), jnp.int32).at[plan.slot].set(
        tok_flat, mode="drop")
    pool_valid = jnp.zeros((ep * Cd,), bool).at[plan.slot].set(
        plan.valid, mode="drop")
    send_x = xl[inv_tok] * pool_valid[:, None].astype(xl.dtype)
    flat_idx = idx.reshape(-1)
    flat_w = r.weights.reshape(-1)
    send_e = jnp.full((ep * Cd,), -1, jnp.int32).at[plan.slot].set(
        flat_idx, mode="drop")
    send_w = jnp.zeros((ep * Cd,), jnp.float32).at[plan.slot].set(
        flat_w, mode="drop")
    send_e = jnp.where(pool_valid, send_e, -1)

    # --- all-to-all ------------------------------------------------------
    a2a = lambda a: jax.lax.all_to_all(
        a.reshape((ep, Cd) + a.shape[1:]), ep_axis, 0, 0, tiled=False
    ).reshape((ep * Cd,) + a.shape[1:])
    recv_x = a2a(send_x)
    recv_e = a2a(send_e)
    recv_w = a2a(send_w)

    # --- local Stages 2-5 on received rows (K'=1) -------------------------
    rank = jax.lax.axis_index(ep_axis)
    local_e = jnp.where(recv_e >= 0, recv_e - rank * EL, EL)   # sentinel EL
    r2 = RouterOut(recv_w[:, None], local_e[:, None].astype(jnp.int32),
                   r.aux_loss, r.z_loss)
    import dataclasses as _dc
    inner_cfg = _dc.replace(moe_cfg, experts_per_token=1)
    # expected local rows ~ T_loc*K (uniform routing); pool sized with the
    # same capacity slack
    inner_pool = round_up(int(math.ceil(
        moe_cfg.capacity_factor * T_loc * K)), 8)
    out_rows, inner_plan = dispatch_compute_combine(
        gate, up, down, recv_x, r2, inner_cfg, expert_offset=0,
        local_experts=EL, backend=stage45_backend(moe_cfg),
        pool_rows=inner_pool)

    # --- reverse all-to-all + per-token sum over K slots ------------------
    back = a2a(out_rows)
    safe_slot = jnp.minimum(plan.slot, ep * Cd - 1)
    yk = back[safe_slot] * plan.valid[:, None].astype(back.dtype)
    out_local = yk.reshape(T_loc, K, d).sum(axis=1)

    aux, z = r.aux_loss, r.z_loss
    for ax in manual:
        aux = jax.lax.pmean(aux, ax)
        z = jax.lax.pmean(z, ax)
    # send-side capacity drops (outer plan) + receive-side pool overflow
    # (inner plan); counts come from the received rows each rank dispatched
    # among its local experts
    stats = _fsmoe_stats(inner_plan.counts, plan.drops, ep_axis=ep_axis,
                         batch_axes=batch_axes, manual=manual,
                         extra_drops=inner_plan.drops)
    if placement is not None:  # back to global expert order
        stats = MoeStats(stats.counts[placement], stats.drops)
    return out_local, aux, z, stats


# ----------------------------------------------------------------------------
# beyond-paper: explicit expert-tensor-parallel path (shard_map)
# ----------------------------------------------------------------------------

def moe_etp_shard_map(p, x, moe_cfg, *, mesh, tp_axis: str = "model",
                      batch_axes=("data",), dropless: bool = False,
                      placement=None):
    """Beyond-paper optimization (EXPERIMENTS §Perf, mixtral hillclimb).

    When E < the model-axis size (mixtral: 8 experts on a 16-way axis), the
    auto-partitioned capacity path reshards tokens *and* the slot pool across
    the mesh, generating TB-scale gather/scatter collectives. This explicit
    path exploits that under expert-TP the expert weights are *replicated*
    across 'model' except for their d_ff shard: every rank can dispatch its
    own data shard locally (sort + pool stay rank-local) and compute partial
    expert outputs with its f-shard; the ONLY cross-rank communication is a
    psum over 'model' of the combined (T_local, d) output — exactly one
    all-reduce per MoE layer, like a Megatron MLP.
    """
    from jax.sharding import PartitionSpec as P

    manual = set(mesh.shape.keys())
    batch_axes = tuple(a for a in batch_axes if a in mesh.shape)
    token_spec = P(tuple(batch_axes), None) if batch_axes else P(None, None)

    def body(router_w, gate, up, down, xl, pl=None):
        r = route(xl, router_w, num_experts=moe_cfg.num_experts,
                  top_k=moe_cfg.experts_per_token,
                  forced_uniform=moe_cfg.forced_uniform_routing)
        rd = r if pl is None else \
            RouterOut(r.weights, pl[r.indices], r.aux_loss, r.z_loss)
        out_partial, plan = dispatch_compute_combine(
            gate, up, down, xl, rd, moe_cfg, backend="xla",
            dropless=dropless)
        out = jax.lax.psum(out_partial, tp_axis)
        aux, z = r.aux_loss, r.z_loss
        for ax in manual:
            aux = jax.lax.pmean(aux, ax)
            z = jax.lax.pmean(z, ax)
        # all E experts are local here (EP=1): counts/drops are per token
        # shard — psum over token-partitioning axes, pmean over replicating
        # ones (every tp rank ran the identical dispatch)
        counts = plan.counts if pl is None else plan.counts[pl]
        counts = counts.astype(jnp.float32)
        drops = plan.drops.astype(jnp.float32)
        for ax in manual:
            if ax in batch_axes:
                counts = jax.lax.psum(counts, ax)
                drops = jax.lax.psum(drops, ax)
            else:
                counts = jax.lax.pmean(counts, ax)
                drops = jax.lax.pmean(drops, ax)
        return out, aux, z, MoeStats(counts, drops)

    operands = [p["router"], p["gate"], p["up"], p["down"], x]
    in_specs = [P(), P(None, None, tp_axis), P(None, None, tp_axis),
                P(None, tp_axis, None), token_spec]
    if placement is not None:
        operands.append(jnp.asarray(placement, jnp.int32))
        in_specs.append(P(None))
    out, aux, z, stats = jax.shard_map(
        body, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(token_spec, P(), P(), MoeStats(P(None), P())),
        axis_names=manual)(*operands)
    out = checkpoint_name(out, "moe_out")
    if moe_cfg.num_shared_experts:
        out = out + _shared_expert(p, x)
    return out, RouterOut(None, None, aux, z), stats


# ----------------------------------------------------------------------------
# top-level block entry
# ----------------------------------------------------------------------------

def sparse_moe_block(p, x, cfg, *, mesh=None, ep_axis: str = "model",
                     batch_axes=("data",), constrain=None, c_align: int = 1,
                     tp_mesh=None, tp_axis=None, placement=None):
    """x: (B, S, d) -> (out (B,S,d), aux_loss, z_loss, MoeStats). The
    dispatch mode comes from ``cfg.moe.dispatch``; ``tp_axis`` (a plan
    mesh's dedicated TP axis) composes expert-TP with the EP shard_map.
    ``placement``: optional (E,) inverse placement row (global expert id
    -> stored position) when the stacked expert weights are re-placed."""
    B, S, d = x.shape
    m = cfg.moe
    dropless = m.dispatch == "dropless"
    xt = x.reshape(B * S, d)
    if m.moe_impl == "naive":
        out, r = moe_naive(p, xt, m, placement=placement)
        # stats from the router's global ids — already placement-free
        one_hot = jax.nn.one_hot(r.indices, m.num_experts, dtype=jnp.float32)
        stats = MoeStats(one_hot.sum((0, 1)), jnp.zeros((), jnp.float32))
        return out.reshape(B, S, d), r.aux_loss, r.z_loss, stats
    use_ep = (m.moe_impl == "fsmoe" and mesh is not None
              and ep_axis in mesh.shape
              and m.num_experts % mesh.shape[ep_axis] == 0)
    if use_ep:
        out, r, stats = moe_fsmoe_ep(p, xt, m, mesh=mesh, ep_axis=ep_axis,
                                     batch_axes=batch_axes, tp_axis=tp_axis,
                                     dropless=dropless, placement=placement)
        return out.reshape(B, S, d), r.aux_loss, r.z_loss, stats
    if m.etp_shard_map and tp_mesh is not None:
        out, r, stats = moe_etp_shard_map(p, xt, m, mesh=tp_mesh,
                                          tp_axis=tp_axis or "model",
                                          batch_axes=batch_axes,
                                          dropless=dropless,
                                          placement=placement)
        return out.reshape(B, S, d), r.aux_loss, r.z_loss, stats
    backend = stage45_backend(m) if m.moe_impl == "fsmoe" else "xla"
    out, r, stats = _moe_dense(p, xt, m, backend=backend, constrain=constrain,
                               c_align=c_align, dropless=dropless,
                               placement=placement)
    return out.reshape(B, S, d), r.aux_loss, r.z_loss, stats
