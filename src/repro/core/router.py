"""MoE router: top-k softmax routing, load-balance aux loss, router z-loss,
and FUR (Forced Uniform Routing, paper §2.3).

The router is replicated across EP ranks (paper §3.1: "the experts and the
router ... are divided and replicated among the EP ranks respectively").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RouterOut(NamedTuple):
    weights: jax.Array      # (T, K) combine weights
    indices: jax.Array      # (T, K) int32 expert ids
    aux_loss: jax.Array     # scalar: load-balance loss (OLMoE-style)
    z_loss: jax.Array       # scalar: router z-loss


def route(x: jax.Array, router_w: jax.Array, *, num_experts: int, top_k: int,
          forced_uniform: bool = False) -> RouterOut:
    """x: (T, d); router_w: (d, E)."""
    T = x.shape[0]
    logits = (x @ router_w.astype(x.dtype)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)

    if forced_uniform:
        # FUR: every expert receives the same number of tokens in the same
        # pattern — isolates load-imbalance effects from scaling studies.
        t = jnp.arange(T, dtype=jnp.int32)[:, None]
        k = jnp.arange(top_k, dtype=jnp.int32)[None, :]
        indices = (t * top_k + k) % num_experts
        weights = jnp.full((T, top_k), 1.0 / top_k, jnp.float32)
    else:
        weights, indices = jax.lax.top_k(probs, top_k)
        indices = indices.astype(jnp.int32)

    # load-balance auxiliary loss: E * sum_e f_e * p_e  (Switch/OLMoE form)
    one_hot = jax.nn.one_hot(indices, num_experts, dtype=jnp.float32)  # (T,K,E)
    f = one_hot.sum(axis=(0, 1)) / (T * top_k)        # fraction dispatched
    p = probs.mean(axis=0)                            # mean router prob
    aux = num_experts * jnp.sum(f * p)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return RouterOut(weights, indices, aux, z)
