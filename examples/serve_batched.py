"""Continuous-batching serving example: a mixtral-style MoE with a
sliding-window ring KV cache behind the ServeEngine — requests with
different prompt lengths, generation lengths, and sampling params share a
fixed slot batch; finished requests are evicted and the freed slots
re-admit queued ones mid-flight.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serve import SamplingParams, ServeEngine


def main():
    cfg = reduced(get_config("mixtral-8x7b"), d_model=128)
    cfg = dataclasses.replace(cfg, sliding_window=32)  # ring-buffer cache
    params = init_params(jax.random.PRNGKey(0), cfg)

    engine = ServeEngine(params, cfg, num_slots=4, max_len=128)
    print(f"slots={engine.pool.num_slots}, window={cfg.sliding_window}, "
          f"cache k shape per layer: {engine.pool.cache['kv']['k'].shape[1:]} "
          f"(ring buffer — O(window), not O(seq))")

    rng = np.random.RandomState(0)
    n_requests = 12
    for i in range(n_requests):
        prompt = rng.randint(1, cfg.vocab_size, size=rng.randint(4, 24))
        engine.submit(
            prompt.tolist(),
            max_new_tokens=int(rng.randint(8, 32)),
            sampling=SamplingParams(temperature=0.7 if i % 2 else 0.0,
                                    top_k=32, top_p=0.95, seed=i))

    t0 = time.time()
    results = engine.run()
    dt = time.time() - t0
    print(f"served {n_requests} requests / {engine.tokens_generated} tokens "
          f"in {engine.steps} engine steps, {dt:.2f}s "
          f"({engine.tokens_generated / dt:.0f} tok/s on CPU)")
    for rid in sorted(results)[:4]:
        r = results[rid]
        print(f"  req {rid}: prompt={r.prompt_len} -> {len(r.tokens)} tokens "
              f"({r.finish_reason}): {r.tokens[:8]}...")


if __name__ == "__main__":
    main()
