"""Batched serving example: sliding-window KV-cache decode for a
mixtral-style MoE (the long_500k-capable configuration) with continuous
batched greedy generation.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs import get_config, reduced
from repro.models import init_params, init_cache, decode_step


def main():
    cfg = reduced(get_config("mixtral-8x7b"), d_model=128)
    cfg = dataclasses.replace(cfg, sliding_window=32)  # ring-buffer cache
    params = init_params(jax.random.PRNGKey(0), cfg)

    B, steps = 8, 64
    cache = init_cache(cfg, B, steps, jnp.float32)
    print(f"batch={B}, window={cfg.sliding_window}, "
          f"cache k shape per layer: {cache['kv']['k'].shape[1:]} "
          f"(ring buffer — O(window), not O(seq))")

    step = jax.jit(lambda p, t, c, i: decode_step(p, t, c, i, cfg,
                                                  compute_dtype=jnp.float32))
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab_size)
    t0 = time.time()
    for i in range(steps):
        logits, cache = step(params, tok, cache, i)
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size], -1).astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"generated {B}x{steps} tokens in {dt:.2f}s "
          f"({B * steps / dt:.0f} tok/s on CPU)")
    print("last tokens:", tok[:, 0].tolist())


if __name__ == "__main__":
    main()
