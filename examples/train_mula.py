"""Paper Figure 1 reproduction (reduced scale): iso-compute dense vs MoE.

Trains mula-1b-smoke (dense) and mula-7b-a1b-smoke (MoE with the same
active-parameter compute) on the same synthetic corpus for the same number
of steps and writes both loss curves. The paper's finding at full scale —
"at iso compute MoE models are more accurate than dense models" — shows up
here as the MoE curve dropping below the dense one.

    PYTHONPATH=src python examples/train_mula.py [--steps 150]
This is the end-to-end training driver deliverable (b): real data pipeline,
checkpointing, NaN monitoring, scheduler — the full substrate.
"""
import argparse
import json
import sys

sys.path.insert(0, "src")

from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--out", default="runs/fig1")
    args = ap.parse_args()

    curves = {}
    # iso-compute: dense d_ff = 2*d_model == MoE top-2 x (expert d_ff = d_model)
    for arch, kw in (("mula-1b", {"d_ff": 2 * args.d_model}),
                     ("mula-7b-a1b", {"moe_dff": args.d_model})):
        print(f"\n=== training {arch} (reduced, iso-compute) ===")
        hist = run(arch, steps=args.steps, batch=args.batch, seq=args.seq,
                   d_model=args.d_model, layers=args.layers,
                   out=f"{args.out}/{arch}", **kw)
        curves[arch] = [h["loss"] for h in hist]

    with open(f"{args.out}/curves.json", "w") as f:
        json.dump(curves, f)

    d, m = curves["mula-1b"], curves["mula-7b-a1b"]
    n = max(len(d) // 10, 1)
    print("\nstep      dense(mula-1b)   moe(mula-7b-a1b)")
    for i in range(0, len(d), n):
        print(f"{i:5d}     {d[i]:8.4f}         {m[i]:8.4f}")
    print(f"final     {d[-1]:8.4f}         {m[-1]:8.4f}")
    print(f"\nMoE - dense final loss: {m[-1] - d[-1]:+.4f} "
          f"(paper Fig 1: MoE lower at iso compute)")


if __name__ == "__main__":
    main()
