"""Fault-tolerance demo (paper §4): a training run survives a hard node
failure and a soft (NaN) failure via buffer nodes + dual checkpointing.

    PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import sys
import tempfile

import jax

sys.path.insert(0, "src")

from repro.checkpoint import Checkpointer
from repro.configs import TrainConfig, ParallelConfig, get_config, reduced
from repro.ft import ClusterManager, NodeFailure, run_with_failure_handling
from repro.train import init_state, make_train_step


def main():
    cfg = reduced(get_config("mula-7b-a1b"), d_model=64)
    tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                     grad_reduce_dtype="float32", warmup_steps=5,
                     total_steps=40, lr_peak=1e-3, lr_min=1e-4)
    state = init_state(jax.random.PRNGKey(0), cfg, tc)
    step_fn = jax.jit(make_train_step(cfg, ParallelConfig(), tc))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 65), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    failures = {"hard": False, "soft": False}

    def train_one_step(state, step):
        if step == 13 and not failures["hard"]:
            failures["hard"] = True
            print(f"  !! injecting HARD failure (segfault) on node 2 @ step {step}")
            raise NodeFailure(2, "hard")
        state, m = step_fn(state, batch)
        loss = float(m["loss"])
        if step == 22 and not failures["soft"]:
            failures["soft"] = True
            print(f"  !! injecting SOFT failure (NaN loss) on node 1 @ step {step}")
            return state, {"loss": loss, "per_rank_losses": [loss, float("nan")]}
        if step % 10 == 0:
            print(f"  step {step:3d} loss {loss:.4f}")
        return state, {"loss": loss, "per_rank_losses": [loss, loss]}

    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(tmp, interval=5)
        cluster = ClusterManager(n_active=4, n_buffer=2)
        state, step, relaunches = run_with_failure_handling(
            train_one_step, state=state, checkpointer=ck, cluster=cluster,
            num_steps=40)
        print(f"\ncompleted {step} steps with {relaunches} relaunches")
        print(f"node replacements (failed -> buffer): {cluster.replaced}")
        assert relaunches == 2 and step == 40
        print("fault-tolerance demo OK")


if __name__ == "__main__":
    main()
