"""Kernel tile autotuning bench: measured autotuned-vs-default timings.

Two parts:

* spec-level (``run(report)``, used by benchmarks/run.py): structural
  quantities for the TPU target — the double-buffered VMEM working set of
  candidate tile triples against each registered HardwareSpec budget
  (``roofline.gmm_working_set_bytes``, the same math the KernelPlan
  guardrail enforces) plus interpret-mode validation latency;

* measured (``python benchmarks/bench_kernels.py``): runs the autotuner's
  measurement path (kernels/autotune.py — explicit warmup,
  ``block_until_ready``, median-of-N, analytic VMEM pruning before any
  compile) on production-aspect gmm shape buckets and records
  autotuned-vs-default tile timings into ``BENCH_kernels.json`` at the
  repo root. ``--write-table`` additionally refreshes the committed
  tuning table (src/repro/kernels/tuning_table.json) that
  ``KernelPlan(tiles='auto')`` resolves from.

Shape buckets: production aspect ratios at 1/8 scale — the full mixtral
(K=4096, N=14336) / dbrx (K=6144, N=10752) expert shapes take minutes per
call under CPU interpret mode; the scaled shapes keep the same K:N aspect
and tile-sensitivity while staying benchable. On real hardware pass
``--full-shapes``. Timings are interpret-mode walltime: tile sizes change
the grid/loop structure, so the ordering is meaningful even though the
absolute numbers are not TPU numbers; ``check_regression.py::check_kernels``
gates best <= default per bucket and vs the committed baseline.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(ROOT, "src") not in sys.path:      # direct-script invocation
    sys.path.insert(0, os.path.join(ROOT, "src"))

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ops, ref
from repro.launch.roofline import (HARDWARE, calibrate_sim_cpu,
                                   gmm_working_set_bytes)

# production aspect ratios (expert d_model x d_ff), 1/8 scale (see module
# docstring); uniform groups of m/g rows keep every tile_m <= m/g valid
BUCKETS = {
    "tiny": {"g": 2, "m": 256, "k": 64, "n": 128},
    "mixtral-8x7b/8": {"g": 2, "m": 256, "k": 512, "n": 1792},
    "dbrx-132b/8": {"g": 2, "m": 256, "k": 768, "n": 1344},
    # mula-7b-a1b's local expert shapes on the dp=2,ep=2,tp=2 mesh
    # (g=E/ep=32, k=d=2048, n=f/tp=512 and the transposed down proj): the
    # dryrun --parallel attribution finds these via the nearest-m fallback,
    # so predicted-vs-measured populates for the flagship arch
    "mula-7b-a1b/gate-up": {"g": 32, "m": 256, "k": 2048, "n": 512},
    "mula-7b-a1b/down": {"g": 32, "m": 256, "k": 512, "n": 2048},
}
FULL_BUCKETS = {
    "tiny": BUCKETS["tiny"],
    "mixtral-8x7b": {"g": 8, "m": 2048, "k": 4096, "n": 14336},
    "dbrx-132b": {"g": 16, "m": 2048, "k": 6144, "n": 10752},
}
DEFAULT_TILES = (128, 512, 512)


def run(report):
    # structural: double-buffered working set of candidate tile triples vs
    # each registered hardware budget (what the KernelPlan guardrail checks)
    for name, tiles in [("mxu_128x512x512", (128, 512, 512)),
                        ("mxu_256x512x1024", (256, 512, 1024)),
                        ("mxu_128x1024x1024", (128, 1024, 1024))]:
        ws = gmm_working_set_bytes(*tiles)
        fits = {hw.name: ws <= hw.vmem_bytes for hw in HARDWARE.values()}
        report(f"gmm_vmem_per_step[{name}]", ws / 2**20 * 1000,
               derived=f"{ws / 2**20:.2f}MiB double-buffered; fits: " +
                       ", ".join(f"{k}={v}" for k, v in fits.items()))

    # interpret-mode correctness latency (the CI cost of kernel validation);
    # the small tile size is scoped to this block — no leak into later benches
    import dataclasses
    small = dataclasses.replace(ops.current_kernel_plan(), tile_m=8)
    with ops.use_kernel_plan(small):
        gs = jnp.array([64, 32, 0, 32], jnp.int32)
        x = jax.random.normal(jax.random.PRNGKey(0), (128, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 64))
        t0 = time.perf_counter()
        out = ops.gmm(x, w, gs)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) * 1e6
        err = float(jnp.abs(out - ref.gmm_ref(x, w, gs)).max())
    report("gmm_interpret_validate", dt, derived=f"max_err={err:.2e}")


# ---------------------------------------------------------------------------
# measured: the autotuner on production shape buckets
# ---------------------------------------------------------------------------

# buckets the tgmm (weight-gradient) autotune also measures: tiny for CI
# coverage plus the flagship arch's expert shapes, whose tgmm rows
# ops._gmm_bwd resolves under tiles='auto'. The rest stay gmm-only to
# bound interpret-mode bench time.
TGMM_BUCKETS = ("tiny", "mula-7b-a1b/gate-up", "mula-7b-a1b/down")


def measure(buckets: dict, *, n_iters: int = 5, hw: str = "tpu-v5e") -> dict:
    measured_hw = calibrate_sim_cpu()
    print(f"calibration: {measured_hw.description}")
    table = autotune.TuningTable(hw=hw)
    points = []
    jobs = [("gmm", name, dims) for name, dims in buckets.items()]
    jobs += [("tgmm", name, dims) for name, dims in buckets.items()
             if name in TGMM_BUCKETS]
    for kernel, name, dims in jobs:
        table = autotune.autotune(
            kernel, [dims], backend="pallas", n_iters=n_iters, hw=hw,
            measured_hw=measured_hw, validate=True, table=table,
            default_tiles=DEFAULT_TILES,
            log=lambda m, tag=f"{kernel}:{name}": print(f"[{tag}] {m}"))
        e = table.find(kernel, "pallas", dims)
        if e is None:
            raise SystemExit(f"{kernel} bucket {name}: no candidate "
                             f"survived")
        ws = gmm_working_set_bytes(*e["tiles"])
        points.append({
            "name": name, "kernel": kernel, "backend": "pallas",
            "bucket": autotune.bucket_key(kernel, dims), "shape": dims,
            "default_tiles": e["default_tiles"],
            "default_ms": e["default_time_ms"],
            "best_tiles": e["tiles"], "best_ms": e["time_ms"],
            "speedup": e["default_time_ms"] / e["time_ms"],
            "gflops": e.get("gflops"),
            "achieved_frac": e.get("achieved_frac"),
            "vmem_ok": ws <= HARDWARE[hw].vmem_bytes,
            "n_iters": n_iters,
        })
    return {
        "target_hw": hw,
        "measured_hw": {"name": measured_hw.name,
                        "peak_flops": measured_hw.peak_flops,
                        "hbm_bw": measured_hw.hbm_bw,
                        "description": measured_hw.description},
        "n_iters": n_iters,
        "kernel_points": points,
        "_table": table,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-iters", type=int, default=5,
                    help="timed reps per candidate (median is recorded)")
    ap.add_argument("--tiny", action="store_true",
                    help="CI bench-smoke mode: tiny bucket only, "
                         "median-of-3")
    ap.add_argument("--full-shapes", action="store_true",
                    help="unscaled production expert shapes (real "
                         "accelerators only — minutes per call under "
                         "interpret mode)")
    ap.add_argument("--hw", default="tpu-v5e", choices=sorted(HARDWARE),
                    help="HardwareSpec whose VMEM budget prunes candidates")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_kernels.json"))
    ap.add_argument("--write-table", action="store_true",
                    help="also refresh the committed tuning table "
                         "(src/repro/kernels/tuning_table.json)")
    ap.add_argument("--table-out", default=autotune.DEFAULT_TABLE_PATH,
                    help="tuning-table path for --write-table")
    args = ap.parse_args(argv)

    buckets = FULL_BUCKETS if args.full_shapes else BUCKETS
    if args.tiny:
        buckets = {"tiny": BUCKETS["tiny"]}
        args.n_iters = min(args.n_iters, 3)

    result = measure(buckets, n_iters=args.n_iters, hw=args.hw)
    table = result.pop("_table")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    for p in result["kernel_points"]:
        ach = (f" achieved={100 * p['achieved_frac']:.1f}%"
               if p.get("achieved_frac") is not None else "")
        print(f"{p['kernel'] + ':' + p['name']:24s} "
              f"default {p['default_ms']:7.1f}ms "
              f"{'x'.join(map(str, p['default_tiles']))} -> best "
              f"{p['best_ms']:7.1f}ms "
              f"{'x'.join(map(str, p['best_tiles']))} "
              f"({p['speedup']:.2f}x){ach}")
    print(f"wrote {args.out}")
    if args.write_table:
        path = table.save(args.table_out)
        print(f"wrote tuning table {path} ({len(table.entries)} entries)")


if __name__ == "__main__":
    main()
