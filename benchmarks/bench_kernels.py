"""Kernel microbenchmarks: Pallas (interpret) vs XLA reference walltime is
meaningless on CPU, so this bench reports the *structural* quantities that
matter on the TPU target: VMEM working set per grid step and grid sizes for
the production shapes, plus interpret-mode validation latency."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def run(report):
    # production-shaped gmm tiles (dbrx expert: d=6144, f=10752)
    for name, (tm, tk, tn) in [("mxu_128x512x512", (128, 512, 512)),
                               ("mxu_256x512x1024", (256, 512, 1024))]:
        vmem = (tm * tk * 2 + tk * tn * 2 + tm * tn * 4) / 2**20
        report(f"gmm_vmem_per_step[{name}]", vmem * 1000,
               derived=f"{vmem:.2f}MiB of ~16MiB v5e VMEM "
                       f"(double-buffer ok: {vmem * 2 < 14})")

    # interpret-mode correctness latency (the CI cost of kernel validation);
    # the small tile size is scoped to this block — no leak into later benches
    import dataclasses
    small = dataclasses.replace(ops.current_kernel_plan(), tile_m=8)
    with ops.use_kernel_plan(small):
        gs = jnp.array([64, 32, 0, 32], jnp.int32)
        x = jax.random.normal(jax.random.PRNGKey(0), (128, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 64))
        t0 = time.perf_counter()
        out = ops.gmm(x, w, gs)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) * 1e6
        err = float(jnp.abs(out - ref.gmm_ref(x, w, gs)).max())
    report("gmm_interpret_validate", dt, derived=f"max_err={err:.2e}")
