"""Paper Figure 1 (proxy): iso-compute dense vs MoE training curves at
reduced scale on the same data pipeline — the MoE model (more total params,
same active params) should reach a lower loss at the same step count."""
from __future__ import annotations

import tempfile

import numpy as np


def run(report, steps: int = 120):
    from repro.launch.train import run as train_run
    # iso-compute: dense d_ff 256 == MoE top-2 x expert-d_ff 128 active
    with tempfile.TemporaryDirectory() as tmp:
        dense = train_run("mula-1b", steps=steps, batch=8, seq=64,
                          out=f"{tmp}/dense", d_model=128, layers=2,
                          d_ff=256, log_every=1000)
        moe = train_run("mula-7b-a1b", steps=steps, batch=8, seq=64,
                        out=f"{tmp}/moe", d_model=128, layers=2,
                        moe_dff=128, log_every=1000)
    ld = float(np.mean([h["loss"] for h in dense[-5:]]))
    lm = float(np.mean([h["loss"] for h in moe[-5:]]))
    report("loss_final_dense[mula-1b-smoke]", ld * 1000)
    report("loss_final_moe[mula-7b-a1b-smoke]", lm * 1000,
           derived=f"moe_minus_dense={lm - ld:+.3f} "
                   f"(paper Fig 1: MoE below dense)")
