"""Paper Table 3, FSMOE column: naive (HF-style) SparseMoE vs the optimized
dispatch pipeline — forward+backward walltime on CPU at reduced scale, plus
compiled-FLOP ratios (the naive path computes every expert on every token:
an analytic E/K compute blowup the measurement should reflect)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import moe as M


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))    # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run(report):
    # dims scaled down but with the paper's E/K structure (OLMoE: 64e top-8)
    for name, E, K, d, f, T in [("mula-7b-like  64e/8", 16, 4, 128, 64, 512),
                                ("mixtral-like   8e/2", 8, 2, 128, 256, 512),
                                ("dbrx-like     16e/4", 16, 4, 128, 128, 512)]:
        cfg = ModelConfig(
            name="b", arch_type="moe", num_layers=1, d_model=d, num_heads=2,
            num_kv_heads=2, d_ff=0, vocab_size=64,
            moe=MoEConfig(num_experts=E, experts_per_token=K, d_ff_expert=f,
                          capacity_factor=1.25))
        p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (T, d))

        def fb(impl):
            def loss(p):
                if impl == "naive":
                    out, _ = M.moe_naive(p, x, cfg.moe)
                else:
                    out, _ = M.moe_dense_capacity(p, x, cfg.moe)
                return (out.astype(jnp.float32) ** 2).sum()
            return jax.jit(jax.value_and_grad(loss))

        t_naive = _time(fb("naive"), p)
        t_fast = _time(fb("fast"), p)
        flops_naive = jax.jit(fb("naive")).lower(p).compile().cost_analysis()
        flops_fast = jax.jit(fb("fast")).lower(p).compile().cost_analysis()
        fr = float(flops_naive.get("flops", 1)) / max(
            float(flops_fast.get("flops", 1)), 1)
        report(f"fsmoe_fb_naive[{name}]", t_naive)
        report(f"fsmoe_fb_fast[{name}]", t_fast,
               derived=f"speedup={t_naive / t_fast:.2f}x "
                       f"flops_ratio={fr:.2f} analytic={E / K:.1f}")
