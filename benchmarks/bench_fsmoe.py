"""Paper Table 3, FSMOE column: naive (HF-style) SparseMoE vs the optimized
dispatch pipeline — forward+backward walltime on CPU at reduced scale, plus
compiled-FLOP ratios (the naive path computes every expert on every token:
an analytic E/K compute blowup the measurement should reflect).

Direct invocation (``python benchmarks/bench_fsmoe.py [--tiny] [--out ..]``)
races the two dispatch modes — capacity vs dropless — forward+backward at a
starved capacity_factor and writes ``BENCH_moe.json`` (``dispatch_points``),
plus a Zipf-skewed-routing placement race — static identity vs the greedy
LPT rebalanced placement over a simulated-EP bottleneck
(``rebalance_points``; gated by ``check_regression.py::check_rebalance``:
rebalanced throughput at least static, dropless stays drop-free).
The structural gate (``check_regression.py``): dropless must report zero
drops and conserve routed pairs at every point, while capacity demonstrably
drops; step times are only loosely bounded (the dropless CPU lowering is an
expert-masked batched contraction costing EL dense matmuls — the wallclock
gap is a lowering artifact, not the accelerator story).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(ROOT, "src") not in sys.path:      # direct-script invocation
    sys.path.insert(0, os.path.join(ROOT, "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import moe as M


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))    # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run(report):
    # dims scaled down but with the paper's E/K structure (OLMoE: 64e top-8)
    for name, E, K, d, f, T in [("mula-7b-like  64e/8", 16, 4, 128, 64, 512),
                                ("mixtral-like   8e/2", 8, 2, 128, 256, 512),
                                ("dbrx-like     16e/4", 16, 4, 128, 128, 512)]:
        cfg = ModelConfig(
            name="b", arch_type="moe", num_layers=1, d_model=d, num_heads=2,
            num_kv_heads=2, d_ff=0, vocab_size=64,
            moe=MoEConfig(num_experts=E, experts_per_token=K, d_ff_expert=f,
                          capacity_factor=1.25))
        p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (T, d))

        def fb(impl):
            def loss(p):
                if impl == "naive":
                    out, _ = M.moe_naive(p, x, cfg.moe)
                else:
                    out, _ = M.moe_dense_capacity(p, x, cfg.moe)
                return (out.astype(jnp.float32) ** 2).sum()
            return jax.jit(jax.value_and_grad(loss))

        t_naive = _time(fb("naive"), p)
        t_fast = _time(fb("fast"), p)
        flops_naive = jax.jit(fb("naive")).lower(p).compile().cost_analysis()
        flops_fast = jax.jit(fb("fast")).lower(p).compile().cost_analysis()
        fr = float(flops_naive.get("flops", 1)) / max(
            float(flops_fast.get("flops", 1)), 1)
        report(f"fsmoe_fb_naive[{name}]", t_naive)
        report(f"fsmoe_fb_fast[{name}]", t_fast,
               derived=f"speedup={t_naive / t_fast:.2f}x "
                       f"flops_ratio={fr:.2f} analytic={E / K:.1f}")


# ----------------------------------------------------------------------------
# dispatch race: capacity vs dropless -> BENCH_moe.json ('dispatch_points')
# ----------------------------------------------------------------------------

_TINY_SHAPES = [("tiny           8e/2", 8, 2, 64, 32, 256)]
_SHAPES = _TINY_SHAPES + [     # (name, E, K, d, f, T) — paper E/K structure
    ("mixtral-like   8e/2", 8, 2, 128, 256, 512),
    ("dbrx-like     16e/4", 16, 4, 128, 128, 512),
]

# starved pool: the capacity points must demonstrably drop so the gate can
# assert the dropless points' zero is meaningful
_STARVED_CF = 0.5


def measure_dispatch(*, tiny: bool = False, iters: int = 5) -> dict:
    points = []
    for name, E, K, d, f, T in (_TINY_SHAPES if tiny else _SHAPES):
        cfg = ModelConfig(
            name="b", arch_type="moe", num_layers=1, d_model=d, num_heads=2,
            num_kv_heads=2, d_ff=0, vocab_size=64,
            moe=MoEConfig(num_experts=E, experts_per_token=K, d_ff_expert=f,
                          capacity_factor=_STARVED_CF))
        p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (T, d))

        def fb(dispatch):
            def loss(p):
                out, _, stats = (M.moe_dropless(p, x, cfg.moe)
                                 if dispatch == "dropless"
                                 else M._moe_dense(p, x, cfg.moe,
                                                   backend="xla"))
                return (out.astype(jnp.float32) ** 2).sum(), stats
            return jax.jit(jax.value_and_grad(loss, has_aux=True))

        row = {"shape": name.strip(), "experts": E, "top_k": K,
               "d_model": d, "d_ff_expert": f, "tokens": T,
               "capacity_factor": _STARVED_CF}
        for dispatch in ("capacity", "dropless"):
            fn = fb(dispatch)
            (val, stats), _ = fn(p)       # compile + stats
            t_us = _time(fn, p, iters=iters)
            row[dispatch] = {
                "step_time_ms": t_us / 1e3,
                "drops": int(stats.drops),
                "counts_sum": int(stats.counts.sum()),
                "routed_pairs": T * K,
            }
        points.append(row)
    return {"tiny": tiny, "capacity_factor": _STARVED_CF,
            "dispatch_points": points}


# ----------------------------------------------------------------------------
# rebalance race: static vs greedy placement under Zipf-skewed routing
#                 -> BENCH_moe.json ('rebalance_points')
# ----------------------------------------------------------------------------

def measure_rebalance(*, tiny: bool = False, iters: int = 5, ep: int = 4,
                      zipf_a: float = 1.2) -> dict:
    """Skewed-routing placement race (parallel/placement.py).

    Tokens point along the router column of a Zipf-drawn expert, so the
    *real* top-k routing is hot-headed: under the identity placement the
    low-id ranks host every hot expert. The race times the simulated-EP
    bottleneck — one host cannot run a real EP all-to-all, so the per-rank
    step is modeled as (rank's routed tokens) x (measured per-token expert
    FFN cost) and the step time is the max over ranks. Greedy LPT placement
    from the same counts must recover throughput; dropless dispatch stays
    drop-free under either placement (placements are pure data movement).
    """
    import numpy as np
    from repro.parallel.placement import greedy_perm, imbalance, rank_loads

    points = []
    for name, E, K, d, f, T in (_TINY_SHAPES if tiny else _SHAPES):
        cfg = ModelConfig(
            name="b", arch_type="moe", num_layers=1, d_model=d, num_heads=2,
            num_kv_heads=2, d_ff=0, vocab_size=64,
            moe=MoEConfig(num_experts=E, experts_per_token=K, d_ff_expert=f,
                          capacity_factor=2.0))
        p = M.init_moe_block(jax.random.PRNGKey(0), cfg)

        # Zipf-routed inputs: token t sits on expert id_t's router column
        rng = np.random.default_rng(0)
        w = 1.0 / np.arange(1, E + 1, dtype=np.float64) ** zipf_a
        ids = rng.choice(E, size=T, p=w / w.sum())
        router = np.asarray(p["router"], np.float32)          # (d, E)
        x = jnp.asarray(router[:, ids].T * 4.0
                        + rng.normal(0, 0.01, (T, d)), jnp.float32)

        out, _, stats = jax.jit(
            lambda p, x: M.moe_dropless(p, x, cfg.moe))(p, x)
        counts = np.asarray(stats.counts, np.float64)
        drops = int(stats.drops)
        counts_sum = int(counts.sum())

        # measured per-token expert-FFN cost on a calibration batch (big
        # enough that launch overhead amortizes; same shape for both legs)
        gw = jnp.zeros((d, f), jnp.float32)
        dw = jnp.zeros((f, d), jnp.float32)
        calib = jnp.ones((4096, d), jnp.float32)
        ffn = jax.jit(lambda xx: (jax.nn.gelu(xx @ gw) @ dw).sum())
        us_per_tok = _time(ffn, calib, iters=iters) / calib.shape[0]

        row = {"shape": name.strip(), "experts": E, "top_k": K, "ep": ep,
               "d_model": d, "d_ff_expert": f, "tokens": T,
               "zipf_a": zipf_a, "drops": drops, "counts_sum": counts_sum,
               "routed_pairs": T * K}
        legs = {"static": tuple(range(E)),
                "rebalanced": greedy_perm(counts, ep)}
        for leg, perm_row in legs.items():
            loads = rank_loads(counts, perm_row, ep)
            t_ms = float(loads.max()) * us_per_tok / 1e3
            row[leg] = {
                "placement": list(perm_row),
                "imbalance": imbalance(counts, perm_row, ep),
                "max_rank_load": int(loads.max()),
                "step_time_ms": t_ms,
                "tok_s": (T * K) / (t_ms / 1e3) if t_ms > 0 else 0.0,
            }
        points.append(row)
    return {"rebalance_points": points}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI bench-smoke mode: one small shape")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_moe.json"))
    args = ap.parse_args(argv)
    result = measure_dispatch(tiny=args.tiny, iters=args.iters)
    result.update(measure_rebalance(tiny=args.tiny, iters=args.iters))
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=2)
    for row in result["dispatch_points"]:
        c, dl = row["capacity"], row["dropless"]
        print(f"{row['shape']:22s} capacity={c['step_time_ms']:7.2f}ms "
              f"drops={c['drops']:5d} | dropless={dl['step_time_ms']:7.2f}ms "
              f"drops={dl['drops']} "
              f"(counts {dl['counts_sum']}/{dl['routed_pairs']})")
    for row in result["rebalance_points"]:
        s, r = row["static"], row["rebalanced"]
        print(f"{row['shape']:22s} static={s['tok_s']:10.0f}tok/s "
              f"(imb {s['imbalance']:.2f}) | "
              f"rebalanced={r['tok_s']:10.0f}tok/s "
              f"(imb {r['imbalance']:.2f}) drops={row['drops']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
