"""Serving benchmark: continuous vs static batching under a Poisson trace.

Both policies run the same model, the same jitted prefill/decode lowerings
(serve.make_prefill_fn / make_decode_fn), the same slot count and the same
seeded arrival trace; the only difference is scheduling:

  static      collect ``slots`` arrived requests (waiting for stragglers),
              prefill them together, decode in lockstep until *every* row
              hits its budget — finished rows burn padded decode steps and
              freed capacity waits for the batch to drain (the toy loop this
              repo shipped with, and the classic serving baseline);
  continuous  ServeEngine — per-step admission into freed slots, per-slot
              positions, eviction on completion.

Reported per policy: useful tokens/s (wasted padded-row tokens excluded),
p50/p99 per-token latency (inter-token gaps plus arrival->first-token).
Continuous batching must win on throughput — asserted at the bottom; the
driver treats a regression here as a failure.

    PYTHONPATH=src python benchmarks/bench_serve.py [--requests N] [--rate R]
"""
from __future__ import annotations

import argparse
import sys
import time
from collections import deque

import numpy as np

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serve import SamplingParams, ServeEngine
from repro.serve.engine import make_decode_fn, make_prefill_fn, _bucket
from repro.serve.kv_pool import SlotKVPool

MAX_LEN = 64


def make_trace(n: int, rate: float, seed: int = 0):
    """Poisson arrivals (Exp interarrival at ``rate`` req/s), varied prompt
    and generation lengths — the straggler spread is what static batching
    pays for."""
    rng = np.random.RandomState(seed)
    t, trace = 0.0, []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        trace.append({
            "arrival": t,
            "prompt": rng.randint(1, 500, size=rng.randint(4, 20)).tolist(),
            "max_new": int(rng.randint(2, 48)),
            "sampling": SamplingParams(seed=i),
        })
    return trace


def _latencies(arrivals, token_times):
    """Per-token latency: arrival->first token, then inter-token gaps."""
    lats = []
    for arr, times in zip(arrivals, token_times):
        prev = arr
        for t in times:
            lats.append(t - prev)
            prev = t
    return np.array(lats)


def run_continuous(params, cfg, trace, slots, fns):
    engine = ServeEngine(params, cfg, num_slots=slots, max_len=MAX_LEN,
                         decode_fn=fns[0], prefill_fn=fns[1])
    t0 = time.perf_counter()
    for r in trace:
        engine.submit(r["prompt"], r["max_new"], r["sampling"],
                      arrival_time=t0 + r["arrival"])
    while len(engine.scheduler) or engine.active:
        engine.step(now=time.perf_counter())
    dt = time.perf_counter() - t0
    res = engine.results
    lats = _latencies(
        [res[i].arrival_time for i in sorted(res)],
        [res[i].token_times for i in sorted(res)])
    return engine.tokens_generated, dt, lats


def run_static(params, cfg, trace, slots, fns):
    """Lockstep batches of ``slots``: wait for the batch to fill, prefill,
    decode until the slowest row finishes, repeat."""
    pool = SlotKVPool(cfg, slots, MAX_LEN, jnp.float32)
    decode, prefill = fns
    queue = deque(trace)
    t0 = time.perf_counter()
    total, arrivals, token_times = 0, [], []
    while queue:
        batch = [queue.popleft() for _ in range(min(slots, len(queue)))]
        # static batching blocks until the whole batch has arrived
        wait_until = t0 + max(r["arrival"] for r in batch)
        while time.perf_counter() < wait_until:
            time.sleep(0.001)
        B = len(batch)
        last_tok = np.zeros((slots, 1), np.int32)
        positions = np.zeros((slots,), np.int32)
        times = [[] for _ in range(B)]
        for b, r in enumerate(batch):
            L = len(r["prompt"])
            P = _bucket(L, 8)
            toks = np.zeros((1, P), np.int32)
            toks[0, :L] = r["prompt"]
            sp = r["sampling"]
            first, pool.cache = prefill(
                params, jnp.asarray(toks), pool.cache,
                jnp.asarray([b], jnp.int32), jnp.asarray([L], jnp.int32),
                jnp.asarray([sp.seed], jnp.int32),
                jnp.asarray([sp.temperature], jnp.float32),
                jnp.asarray([sp.top_k], jnp.int32),
                jnp.asarray([sp.top_p], jnp.float32))
            last_tok[b, 0] = int(first[0])
            positions[b] = L
            times[b].append(time.perf_counter())
            total += 1
        done = np.array([len(times[b]) >= batch[b]["max_new"]
                         for b in range(B)] + [True] * (slots - B))
        sp = SamplingParams()
        zeros = jnp.zeros((slots,), jnp.int32)
        while not done.all():                      # stragglers gate everyone
            nxt, pool.cache = decode(
                params, jnp.asarray(last_tok), pool.cache,
                jnp.asarray(positions),
                zeros, jnp.zeros((slots,), jnp.float32),
                zeros, jnp.ones((slots,), jnp.float32))
            nxt = np.asarray(nxt)
            now = time.perf_counter()
            for b in range(B):
                positions[b] += 1
                last_tok[b, 0] = nxt[b]
                if not done[b]:                    # padded rows: wasted work
                    times[b].append(now)
                    total += 1
                    done[b] = len(times[b]) >= batch[b]["max_new"]
        arrivals += [t0 + r["arrival"] for r in batch]
        token_times += times
    dt = time.perf_counter() - t0
    return total, dt, _latencies(arrivals, token_times)


def run(report=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=40.0)
    ap.add_argument("--slots", type=int, default=4)
    args, _ = ap.parse_known_args()

    cfg = reduced(get_config("mixtral-8x7b"), d_model=64)
    params = init_params(jax.random.PRNGKey(0), cfg)
    trace = make_trace(args.requests, args.rate)

    # one shared pair of jitted lowerings for BOTH policies, warmed on every
    # prefill bucket in the trace — neither policy's clock sees compile time
    fns = (jax.jit(make_decode_fn(cfg, compute_dtype=jnp.float32)),
           jax.jit(make_prefill_fn(cfg, compute_dtype=jnp.float32)))
    warm = ServeEngine(params, cfg, num_slots=args.slots, max_len=MAX_LEN,
                       decode_fn=fns[0], prefill_fn=fns[1])
    for P in sorted({_bucket(len(r["prompt"]), 8) for r in trace}):
        warm.submit(list(range(1, P + 1)), 2)
        warm.run()

    rows = {}
    for name, fn in [("static", run_static), ("continuous", run_continuous)]:
        toks, dt, lats = fn(params, cfg, trace, args.slots, fns)
        tps = toks / dt
        p50, p99 = np.percentile(lats * 1e3, [50, 99])
        rows[name] = (tps, dt)
        line = (f"{name:>10}: {toks} tokens in {dt:5.2f}s -> {tps:6.1f} tok/s"
                f" | per-token latency p50={p50:6.1f}ms p99={p99:7.1f}ms")
        print(line, flush=True)
        if report is not None:   # the runner's CSV column is us_per_call
            report(f"serve_{name}_per_token", 1e6 / tps,
                   derived=f"{tps:.1f} tok/s p50={p50:.1f}ms "
                           f"p99={p99:.1f}ms")

    speedup = rows["continuous"][0] / rows["static"][0]
    print(f"continuous/static throughput: {speedup:.2f}x")
    # throughput ordering is only meaningful when arrivals saturate the
    # engine; an arrival-bound trace (tiny --requests / slow --rate) has
    # both policies idling at the arrival rate, with noise deciding the sign
    arrival_span = trace[-1]["arrival"]
    if rows["continuous"][1] > 1.2 * arrival_span:
        assert speedup > 1.0, "continuous batching must beat static batching"
    else:
        print("(arrival-bound trace: throughput ordering not asserted)")
    return speedup


if __name__ == "__main__":
    run()
