"""Paper Table 3, EPSO column + Figure 6: SO vs EPSO.

Two parts:

* spec-level (``run(report)``, used by benchmarks/run.py): per MoE model on
  the 16x16 production AbstractMesh, analytic per-device optimizer-state
  bytes (master+m+v fp32) under SO and EPSO — the memory mechanism of
  Figure 6 and, via the update-step roofline, the paper's optimizer-step
  speedup mechanism (1.07-1.36x wall-clock on PVC);

* measured (``python benchmarks/bench_epso.py``): a subprocess with 8 forced
  CPU host devices trains a reduced Mula-7B-A1B on a (4,2) (data, model)
  mesh under ``opt_shard`` in {none, so, epso}, recording *placed* per-device
  optimizer-state bytes (summed over the shards resident on device 0) and
  the post-compile per-step median over ``n_iters`` timed steps (the
  bench_scaling.py shape — a single averaged loop was too flaky to gate on),
  into ``BENCH_epso.json`` at the repo root.

``--overlap`` controls the overlapped optimizer update (optim/overlap.py);
the default 'auto' runs epso with the bucketed ring overlap and keeps
none/so eager, so the recorded epso-vs-so delta is overlapped-vs-eager —
the step-time parity check_regression.py::check_epso_time gates on. Each
mode records the resolved ``opt_overlap`` impl it ran.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(ROOT, "src") not in sys.path:      # direct-script invocation
    sys.path.insert(0, os.path.join(ROOT, "src"))

import jax
import numpy as np

from repro.compat import AxisType  # installs old-jax shims on import
from jax.sharding import AbstractMesh

from repro.configs import get_config
from repro.models import init_params
from repro.optim.epso import state_bytes_per_device
from repro.parallel.sharding import make_rules

MODELS = ["mula-7b-a1b", "mula-20b-a2b", "mula-100b-a7b", "mula-220b-a10b",
          "dbrx-132b", "mixtral-8x7b", "moonshot-v1-16b-a3b"]

MEASURE_MODES = ("none", "so", "epso")


def run(report):
    mesh = AbstractMesh((16, 16), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)
    for name in MODELS:
        cfg = get_config(name)
        shapes = jax.eval_shape(
            lambda c=cfg: init_params(jax.random.PRNGKey(0), c))
        rules = make_rules(cfg, mesh, kind="train", global_batch=256)
        so = state_bytes_per_device(shapes, rules, "so")
        epso = state_bytes_per_device(shapes, rules, "epso")
        report(f"epso_state_bytes_so[{name}]", so / 2**20)
        report(f"epso_state_bytes_epso[{name}]", epso / 2**20,
               derived=f"bytes_ratio={so / epso:.2f}x "
                       f"(paper optimizer speedups: 1.07-1.36x)")


# ---------------------------------------------------------------------------
# measured: simulated 8-device mesh
# ---------------------------------------------------------------------------

def measure(mesh_spec: str = "4,2", steps: int = 10, d_model: int = 64,
            seq: int = 32, batch: int = 8, overlap: str = "auto",
            modes=MEASURE_MODES) -> dict:
    """Runs inside a process whose backend sees enough devices.

    The orchestrating ``main()`` calls this once per mode in its own
    subprocess: timing the modes back-to-back in one process lets the
    earlier modes' compiled executables and allocator state skew the later
    ones (epso, timed last, measured up to ~25% slow purely from ordering).
    """
    import dataclasses
    import time

    from repro.configs import TrainConfig, reduced
    from repro.optim.overlap import resolve_opt_overlap
    from repro.parallel.plan import ParallelPlan
    from repro.train import init_state, make_train_step

    cfg = reduced(get_config("mula-7b-a1b"), d_model=d_model)
    tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                     grad_reduce_dtype="float32", lr_peak=1e-3, lr_min=1e-4,
                     warmup_steps=2, total_steps=steps + 1, seq_len=seq,
                     global_batch=batch)
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, seq + 1), 0,
                              cfg.vocab_size)
    b = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    dev0 = jax.devices()[0]
    out = {}
    rules = None
    for mode in modes:
        pplan = ParallelPlan.from_legacy(mesh_spec, cfg=cfg, opt_shard=mode)
        ov_setting = overlap
        if overlap in ("ring", "xla") and mode == "none":
            # unsharded has no optimizer collectives to overlap; forcing an
            # impl would be rejected by resolve_opt_overlap
            ov_setting = "off"
        if ov_setting != "auto":
            pplan = dataclasses.replace(pplan, opt_overlap=ov_setting)
        plan = pplan.resolve(cfg, global_batch=batch)
        rules = plan.rules
        state = init_state(jax.random.PRNGKey(0), cfg, tc, plan=plan)
        # parallel=None: the plan supplies the ParallelConfig, including the
        # overlap= token, so the built step runs exactly what we record
        step_fn = make_train_step(cfg, None, tc, plan=plan)
        ov = step_fn.opt_overlap_impl
        assert ov == resolve_opt_overlap(plan.opt_overlap, mode, plan.mesh), \
            (mode, ov, plan.opt_overlap)
        # explicit warmup: compile + place, block on the whole output so no
        # async dispatch leaks into the first timed step
        state, m = step_fn(state, b)
        jax.block_until_ready((jax.tree.leaves(state.opt.m)[0], m["loss"]))
        placed = 0
        for leaf in (jax.tree.leaves(state.opt.master)
                     + jax.tree.leaves(state.opt.m)
                     + jax.tree.leaves(state.opt.v)):
            placed += sum(s.data.nbytes for s in leaf.addressable_shards
                          if s.device == dev0)
        # per-step median over n_iters (the bench_scaling.py shape): the
        # forced-host-device simulation shares CPU cores, so a single
        # averaged loop is too flaky for the CI parity gate
        ts = []
        for _ in range(steps):
            t0 = time.perf_counter()
            state, m = step_fn(state, b)
            jax.block_until_ready(m["loss"])
            ts.append(time.perf_counter() - t0)
        dt = sorted(ts)[len(ts) // 2]
        out[mode] = {
            "state_bytes_per_device": int(placed),
            "state_bytes_per_device_analytic": int(
                state_bytes_per_device(state.params, rules, mode)),
            "step_time_ms": dt * 1e3,
            "n_iters": steps,
            "opt_overlap": ov,
        }
    return {"mesh": mesh_spec, "devices": len(jax.devices()),
            "arch": cfg.name, "d_model": d_model, "seq": seq, "batch": batch,
            "n_iters": steps, "overlap": overlap, "modes": out}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="4,2")
    ap.add_argument("--steps", type=int, default=10,
                    help="timed steps per mode (median is recorded)")
    ap.add_argument("--overlap", default="auto",
                    choices=["auto", "off", "ring", "xla"],
                    help="opt_overlap plan option: 'auto' overlaps epso "
                         "(ring) and keeps none/so eager")
    ap.add_argument("--tiny", action="store_true",
                    help="CI bench-smoke mode: median-of-3")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_epso.json"))
    ap.add_argument("--_measure", choices=list(MEASURE_MODES),
                    help=argparse.SUPPRESS)   # child-process mode: one mode
    args = ap.parse_args(argv)
    if args.tiny:
        args.steps = min(args.steps, 3)

    if args._measure:
        print(json.dumps(measure(args.mesh, steps=args.steps,
                                 overlap=args.overlap,
                                 modes=(args._measure,))))
        return

    from repro.launch.mesh import forced_device_env
    shape = [int(x) for x in args.mesh.split(",")]
    env = forced_device_env(int(np.prod(shape)))
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    result = None
    for mode in MEASURE_MODES:          # one subprocess per mode (see measure)
        r = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--_measure", mode,
             "--mesh", args.mesh, "--steps", str(args.steps),
             "--overlap", args.overlap],
            capture_output=True, text=True, env=env, timeout=1800)
        if r.returncode != 0:
            sys.stderr.write(r.stdout + r.stderr)
            raise SystemExit(f"bench_epso measured run failed (mode={mode})")
        part = json.loads(r.stdout.strip().splitlines()[-1])
        if result is None:
            result = part
        else:
            result["modes"].update(part["modes"])
    modes = result["modes"]
    assert modes["epso"]["state_bytes_per_device"] \
        < modes["so"]["state_bytes_per_device"], modes
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    for mode in MEASURE_MODES:
        m = modes[mode]
        print(f"{mode:5s} state_bytes/dev={m['state_bytes_per_device']:>10d} "
              f"step={m['step_time_ms']:.1f}ms (median of {m['n_iters']}, "
              f"overlap={m['opt_overlap']})")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
