"""Paper Table 3, EPSO column + Figure 6: SO vs EPSO.

Reports, per MoE model (paper's Mula family + assigned MoE archs) on the
16x16 production mesh:
  * per-device optimizer-state bytes (master+m+v fp32) under SO and EPSO —
    the memory mechanism of Figure 6;
  * the update-step roofline: optimizer FLOPs and HBM traffic scale with the
    local state shard, so bytes_ratio is the paper's optimizer-step speedup
    mechanism (the paper measures 1.07-1.36x wall-clock on PVC);
  * CPU walltime of one sharded update at reduced scale (SO vs EPSO state
    placement on a host mesh) as a directional measurement.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import AbstractMesh, AxisType

from repro.configs import get_config
from repro.models import init_params
from repro.optim.epso import state_bytes_per_device
from repro.parallel.sharding import make_rules

MODELS = ["mula-7b-a1b", "mula-20b-a2b", "mula-100b-a7b", "mula-220b-a10b",
          "dbrx-132b", "mixtral-8x7b", "moonshot-v1-16b-a3b"]


def run(report):
    mesh = AbstractMesh((16, 16), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)
    for name in MODELS:
        cfg = get_config(name)
        shapes = jax.eval_shape(
            lambda c=cfg: init_params(jax.random.PRNGKey(0), c))
        rules = make_rules(cfg, mesh, kind="train", global_batch=256)
        so = state_bytes_per_device(shapes, rules, "so")
        epso = state_bytes_per_device(shapes, rules, "epso")
        report(f"epso_state_bytes_so[{name}]", so / 2**20)
        report(f"epso_state_bytes_epso[{name}]", epso / 2**20,
               derived=f"bytes_ratio={so / epso:.2f}x "
                       f"(paper optimizer speedups: 1.07-1.36x)")
