"""Bench-regression gate: compare a fresh BENCH_*.json against the
committed baseline with a step-time tolerance, plus the structural
properties each bench is supposed to demonstrate.

Usage (the CI bench-smoke job):
    python benchmarks/bench_scaling.py --tiny --out /tmp/BENCH_pp.fresh.json
    python benchmarks/check_regression.py \
        --fresh /tmp/BENCH_pp.fresh.json --baseline BENCH_pp.json

    python benchmarks/bench_epso.py --tiny --out /tmp/BENCH_epso.fresh.json
    python benchmarks/check_regression.py \
        --fresh /tmp/BENCH_epso.fresh.json --baseline BENCH_epso.json

Checks (kind auto-detected from the JSON shape):

* BENCH_pp — every fresh (pp, schedule) point and (vocab, pp, impl)
  executor point must be within ``--tol``x of the matching baseline step
  time; the per-stage executor must stay at least ``--min-speedup``x the
  masked one at the largest fresh vocab point (the reclaimed head compute
  — a regression here means non-last stages are paying the vocab matmul
  again, even if absolute times sit inside the tolerance band).
* BENCH_epso — per-mode step times within tolerance; placed state bytes
  must order epso < so < none (the paper's memory mechanism); and, when
  the fresh epso point ran with the overlapped update (``opt_overlap``
  recorded as ring/xla), ``check_epso_time`` gates the step-time fix
  itself: overlapped epso must be at parity-or-better with eager so
  (``--epso-parity``) and within ``--epso-vs-none`` of the unsharded
  baseline — the regression this repo's overlap work exists to keep
  fixed. Skipped (with a notice) when the fresh run recorded overlap
  off, so the CI overlap-off leg only exercises the eager path's
  vs-baseline tolerance.
* BENCH_kernels — the autotuner's measured claim: autotuned tiles at
  parity-or-better with the plan default on every shape bucket
  (``--kernel-parity``, in-run so tight), chosen tiles inside the target
  VMEM budget, best times within ``--tol`` of the committed baseline.
* BENCH_moe — per-shape capacity/dropless step times within tolerance;
  structurally, every dropless point must report zero drops AND conserve
  all routed (token, expert) pairs, while the starved capacity points must
  demonstrably drop (otherwise the dropless zero proves nothing). The
  dropless/capacity wallclock ratio is only loosely bounded
  (``--moe-ratio``): the CPU lowering of the ragged grouped matmul costs
  ~E dense matmuls, a lowering artifact rather than the accelerator story.
  The same file's ``rebalance_points`` (the Zipf-skewed placement race)
  are gated by ``check_rebalance``: rebalanced token throughput at
  parity-or-better vs the static placement, no imbalance regression, and
  zero drops / full pair conservation under either placement.

Step-time tolerance is deliberately loose (hardware varies across CI
runners); the structural properties are the tight part of the gate.
Exits non-zero with a per-violation report.
"""
from __future__ import annotations

import argparse
import json
import sys


def _load(path):
    with open(path) as f:
        return json.load(f)


def check_pp(fresh: dict, base: dict, tol: float, min_speedup: float) -> list:
    errors = []
    base_pts = {(p["pp"], p["schedule"]): p for p in base.get("points", [])}
    for p in fresh.get("points", []):
        key = (p["pp"], p["schedule"])
        b = base_pts.get(key)
        if b is None:
            continue
        if p["step_time_ms"] > b["step_time_ms"] * tol:
            errors.append(
                f"pp point {key}: fresh {p['step_time_ms']:.1f}ms > "
                f"{tol}x baseline {b['step_time_ms']:.1f}ms")
    base_exec = {(r["vocab"], r["pp"]): r
                 for r in base.get("executor_points", [])}
    for r in fresh.get("executor_points", []):
        key = (r["vocab"], r["pp"])
        b = base_exec.get(key)
        if b is None:
            continue
        for impl in ("masked", "shardmap"):
            ft = r[impl]["step_time_ms"]
            bt = b[impl]["step_time_ms"]
            if ft > bt * tol:
                errors.append(
                    f"executor point vocab={key[0]} pp={key[1]} {impl}: "
                    f"fresh {ft:.1f}ms > {tol}x baseline {bt:.1f}ms")
    ex = fresh.get("executor_points", [])
    if ex:
        biggest = max(ex, key=lambda r: r["vocab"])
        if biggest["speedup"] < min_speedup:
            errors.append(
                f"per-stage executor speedup at vocab={biggest['vocab']} is "
                f"{biggest['speedup']:.2f}x < required {min_speedup}x — the "
                f"reclaimed embed/head compute regressed")
    return errors


def check_epso(fresh: dict, base: dict, tol: float) -> list:
    errors = []
    for mode, f in fresh.get("modes", {}).items():
        b = base.get("modes", {}).get(mode)
        if b is None:
            continue
        if f["step_time_ms"] > b["step_time_ms"] * tol:
            errors.append(
                f"epso mode {mode}: fresh {f['step_time_ms']:.1f}ms > "
                f"{tol}x baseline {b['step_time_ms']:.1f}ms")
    modes = fresh.get("modes", {})
    if {"so", "epso"} <= modes.keys():
        if modes["epso"]["state_bytes_per_device"] >= \
                modes["so"]["state_bytes_per_device"]:
            errors.append(
                "EPSO placed state bytes not below SO: "
                f"{modes['epso']['state_bytes_per_device']} >= "
                f"{modes['so']['state_bytes_per_device']}")
    if {"so", "none"} <= modes.keys():
        if modes["so"]["state_bytes_per_device"] >= \
                modes["none"]["state_bytes_per_device"]:
            errors.append(
                "SO placed state bytes not below unsharded: "
                f"{modes['so']['state_bytes_per_device']} >= "
                f"{modes['none']['state_bytes_per_device']}")
    return errors


def _epso_table(modes: dict) -> str:
    """Readable per-mode delta table for check_epso_time failures."""
    none_t = modes.get("none", {}).get("step_time_ms")
    lines = [f"  {'mode':6s} {'overlap':8s} {'step_ms':>9s} {'vs none':>8s}"]
    for mode in ("none", "so", "epso"):
        m = modes.get(mode)
        if m is None:
            continue
        rel = (f"{m['step_time_ms'] / none_t:7.2f}x"
               if none_t else f"{'n/a':>8s}")
        lines.append(f"  {mode:6s} {str(m.get('opt_overlap', '?')):8s} "
                     f"{m['step_time_ms']:9.1f} {rel}")
    return "\n".join(lines)


def check_epso_time(fresh: dict, parity_tol: float,
                    vs_none_tol: float) -> list:
    """Gate the overlapped-EPSO step-time fix within one fresh run.

    Only meaningful when the fresh epso point actually ran overlapped —
    that is what moved its collectives off the critical path. In-run
    comparisons (epso vs so vs none from the same process, same median-of-N
    methodology) are far less runner-sensitive than vs-baseline times, so
    the tolerances here can be much tighter than ``--tol``.
    """
    modes = fresh.get("modes", {})
    if not {"none", "so", "epso"} <= modes.keys():
        return []
    ov = modes["epso"].get("opt_overlap")
    if ov in (None, "off"):
        print("check_epso_time: skipped (fresh epso ran with overlap "
              f"{ov!r} — nothing to gate)")
        return []
    errors = []
    et = modes["epso"]["step_time_ms"]
    st = modes["so"]["step_time_ms"]
    nt = modes["none"]["step_time_ms"]
    if et > st * parity_tol:
        errors.append(
            f"overlapped epso ({ov}) step time {et:.1f}ms exceeds "
            f"{parity_tol}x eager so {st:.1f}ms — the step-time "
            f"regression is back:\n" + _epso_table(modes))
    if et > nt * vs_none_tol:
        errors.append(
            f"overlapped epso ({ov}) step time {et:.1f}ms exceeds "
            f"{vs_none_tol}x unsharded baseline {nt:.1f}ms:\n"
            + _epso_table(modes))
    return errors


def check_kernels(fresh: dict, base: dict, tol: float,
                  parity: float) -> list:
    """Gate the kernel autotuner's measured claim: the autotuned tiles are
    no slower than the plan default on every bucket (in-run comparison, so
    ``--kernel-parity`` is tight), the chosen tiles respect the target
    hardware's VMEM budget, and best times stay within ``--tol`` of the
    committed baseline on matching (kernel, bucket) points."""
    errors = []
    base_pts = {(p["kernel"], p["bucket"]): p
                for p in base.get("kernel_points", [])}
    for p in fresh.get("kernel_points", []):
        key = (p["kernel"], p["bucket"])
        # structural: autotuned must not lose to the default it was
        # measured against in the same run
        if p["best_ms"] > p["default_ms"] * parity:
            errors.append(
                f"kernel {key}: autotuned {p['best_ms']:.1f}ms "
                f"({'x'.join(map(str, p['best_tiles']))}) exceeds {parity}x "
                f"default {p['default_ms']:.1f}ms "
                f"({'x'.join(map(str, p['default_tiles']))}) — the tuning "
                f"table would slow this bucket down")
        if not p.get("vmem_ok", True):
            errors.append(
                f"kernel {key}: chosen tiles "
                f"{'x'.join(map(str, p['best_tiles']))} exceed the target "
                f"VMEM budget — the pruner let a spilling config win")
        b = base_pts.get(key)
        if b is None:
            continue
        if p["best_ms"] > b["best_ms"] * tol:
            errors.append(
                f"kernel {key}: fresh best {p['best_ms']:.1f}ms > {tol}x "
                f"baseline best {b['best_ms']:.1f}ms")
    return errors


def check_moe(fresh: dict, base: dict, tol: float, moe_ratio: float) -> list:
    errors = []
    base_pts = {p["shape"]: p for p in base.get("dispatch_points", [])}
    for p in fresh.get("dispatch_points", []):
        shape = p["shape"]
        dl, cap = p["dropless"], p["capacity"]
        # structural gates (the tight part): dropless never drops and
        # accounts for every routed pair
        if dl["drops"] != 0:
            errors.append(f"moe {shape}: dropless reported "
                          f"{dl['drops']} drops (must be 0)")
        if dl["counts_sum"] != dl["routed_pairs"]:
            errors.append(f"moe {shape}: dropless counts_sum "
                          f"{dl['counts_sum']} != routed pairs "
                          f"{dl['routed_pairs']}")
        if cap["drops"] <= 0:
            errors.append(f"moe {shape}: starved capacity point dropped "
                          f"nothing — the dropless zero is untested")
        # wallclock: loose in-run ratio + loose vs-baseline tolerance
        if dl["step_time_ms"] > cap["step_time_ms"] * moe_ratio:
            errors.append(
                f"moe {shape}: dropless {dl['step_time_ms']:.1f}ms > "
                f"{moe_ratio}x capacity {cap['step_time_ms']:.1f}ms")
        b = base_pts.get(shape)
        if b is None:
            continue
        for mode in ("capacity", "dropless"):
            ft = p[mode]["step_time_ms"]
            bt = b[mode]["step_time_ms"]
            if ft > bt * tol:
                errors.append(
                    f"moe {shape} {mode}: fresh {ft:.1f}ms > {tol}x "
                    f"baseline {bt:.1f}ms")
    return errors


def check_rebalance(fresh: dict, base: dict, tol: float) -> list:
    """Gate the skewed-routing placement race (bench_fsmoe.py
    ``rebalance_points``): the greedy rebalanced placement must hold
    parity-or-better token throughput vs the static identity placement
    (in-run comparison — both legs share one measured per-token cost, so
    this is exact placement math, no runner noise), must not worsen the
    rank imbalance, and dropless dispatch must stay drop-free with every
    routed pair conserved under either placement. Throughput is also held
    within ``--tol`` of the committed baseline per shape."""
    errors = []
    base_pts = {p["shape"]: p for p in base.get("rebalance_points", [])}
    for p in fresh.get("rebalance_points", []):
        shape = p["shape"]
        s, r = p["static"], p["rebalanced"]
        if p["drops"] != 0:
            errors.append(f"rebalance {shape}: dropless reported "
                          f"{p['drops']} drops under the skewed routing "
                          f"(must be 0)")
        if p["counts_sum"] != p["routed_pairs"]:
            errors.append(f"rebalance {shape}: counts_sum {p['counts_sum']} "
                          f"!= routed pairs {p['routed_pairs']} — the "
                          f"placement lost tokens")
        if r["tok_s"] < s["tok_s"]:
            errors.append(
                f"rebalance {shape}: rebalanced throughput "
                f"{r['tok_s']:.0f} tok/s below static {s['tok_s']:.0f} "
                f"tok/s — the greedy placement made the bottleneck worse")
        if r["imbalance"] > s["imbalance"]:
            errors.append(
                f"rebalance {shape}: rebalanced imbalance "
                f"{r['imbalance']:.3f} exceeds static {s['imbalance']:.3f}")
        b = base_pts.get(shape)
        if b is None:
            continue
        for leg in ("static", "rebalanced"):
            ft, bt = p[leg]["tok_s"], b[leg]["tok_s"]
            if bt > 0 and ft < bt / tol:
                errors.append(
                    f"rebalance {shape} {leg}: fresh {ft:.0f} tok/s < "
                    f"baseline {bt:.0f} tok/s / {tol}")
    return errors


def check_census(fresh: dict, base: dict, census_tol: float) -> list:
    """Gate ANALYSIS_census.json (the Shardlint trace baseline).

    Structural (tight): every baseline plan present in the fresh census;
    per-collective-kind instruction counts exactly equal (a GSPMD change
    that adds or removes a collective should fail loudly, with the kind
    named); zero sharding-contract violations in the fresh census, and
    the declared contract set unchanged. Bytes (ring model) get a
    ``--census-tol`` factor per kind — shape-bucket padding may legally
    move a few bytes without changing the program's structure.
    """
    errors = []
    fresh_pts = {p["spec"]: p for p in fresh.get("census_points", [])}
    for b in base.get("census_points", []):
        spec = b["spec"]
        f = fresh_pts.get(spec)
        if f is None:
            errors.append(f"census plan {spec!r}: in baseline but missing "
                          f"from the fresh census (matrix dropout)")
            continue
        for v in f.get("violations", []):
            errors.append(f"census plan {spec!r}: contract violation: {v}")
        if sorted(f.get("contracts", [])) != sorted(b.get("contracts", [])):
            errors.append(
                f"census plan {spec!r}: declared contract set changed: "
                f"baseline {sorted(b.get('contracts', []))} vs fresh "
                f"{sorted(f.get('contracts', []))}")
        for kind in sorted(set(b.get("counts", {})) | set(f.get("counts", {}))):
            bc = b.get("counts", {}).get(kind, 0)
            fc = f.get("counts", {}).get(kind, 0)
            if fc != bc:
                errors.append(
                    f"census plan {spec!r}: {kind} count {fc} != baseline "
                    f"{bc} — the lowered program's collective structure "
                    f"changed")
        for kind, bb in b.get("ring_bytes", {}).items():
            fb = f.get("ring_bytes", {}).get(kind, 0.0)
            if bb > 0 and not (bb / census_tol <= fb <= bb * census_tol):
                errors.append(
                    f"census plan {spec!r}: {kind} ring bytes {fb:.3e} "
                    f"outside {census_tol}x of baseline {bb:.3e}")
    return errors


def check_pair(fresh: dict, base: dict, args):
    """Kind-detected checks for one (fresh, baseline) pair ->
    (kind, errors) — kind is None when the JSON shape is unrecognized."""
    if "census_points" in fresh:
        return "census", check_census(fresh, base, args.census_tol)
    if "kernel_points" in fresh:
        return "kernels", check_kernels(fresh, base, args.tol,
                                        args.kernel_parity)
    if "dispatch_points" in fresh or "rebalance_points" in fresh:
        errors = check_moe(fresh, base, args.tol, args.moe_ratio)
        errors += check_rebalance(fresh, base, args.tol)
        return "moe", errors
    if "executor_points" in fresh or "points" in fresh:
        return "pp", check_pp(fresh, base, args.tol, args.min_speedup)
    if "modes" in fresh:
        errors = check_epso(fresh, base, args.tol)
        errors += check_epso_time(fresh, args.epso_parity, args.epso_vs_none)
        return "epso", errors
    return None, []


def discover_baselines(baseline_dir: str) -> list:
    """Committed gate files: every BENCH_*.json / ANALYSIS_*.json in
    ``baseline_dir`` (the repo root in CI)."""
    import glob
    import os
    out = []
    for pat in ("BENCH_*.json", "ANALYSIS_*.json"):
        out += glob.glob(os.path.join(baseline_dir, pat))
    return sorted(out)


def check_all(args) -> int:
    """--all: gate every committed baseline against its fresh counterpart
    ``<fresh-dir>/<STEM>.fresh.json``. A baseline whose fresh file is
    missing FAILS — a bench silently dropping out of CI used to pass."""
    import os
    baselines = discover_baselines(args.baseline_dir)
    if not baselines:
        print(f"check_regression --all: no BENCH_*.json/ANALYSIS_*.json "
              f"under {args.baseline_dir!r}")
        return 2
    failures = 0
    for bpath in baselines:
        stem = os.path.splitext(os.path.basename(bpath))[0]
        fpath = os.path.join(args.fresh_dir, stem + ".fresh.json")
        if not os.path.exists(fpath):
            print(f"BENCH DROPOUT: baseline {bpath} has no fresh run at "
                  f"{fpath} — the bench silently fell out of CI")
            failures += 1
            continue
        fresh, base = _load(fpath), _load(bpath)
        kind, errors = check_pair(fresh, base, args)
        if kind is None:
            print(f"unrecognized bench JSON shape in {fpath}")
            failures += 1
            continue
        if errors:
            print(f"BENCH REGRESSION ({kind}, {stem}): "
                  f"{len(errors)} violation(s)")
            for e in errors:
                print(" -", e)
            failures += 1
        else:
            print(f"bench gate ok ({kind}, {stem})")
    if failures:
        print(f"check_regression --all: {failures} of {len(baselines)} "
              f"baseline(s) failed")
        return 1
    print(f"check_regression --all: {len(baselines)} baseline(s) ok")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default=None)
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--all", action="store_true",
                    help="gate every committed BENCH_*/ANALYSIS_* baseline "
                         "against <fresh-dir>/<STEM>.fresh.json; a missing "
                         "fresh file fails (no silent bench dropout)")
    ap.add_argument("--baseline-dir", default=".",
                    help="--all: directory holding the committed baselines")
    ap.add_argument("--fresh-dir", default="/tmp",
                    help="--all: directory holding the fresh runs")
    ap.add_argument("--tol", type=float, default=2.5,
                    help="step-time regression factor vs baseline")
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="required shardmap-vs-masked speedup at the "
                         "largest fresh vocab point")
    ap.add_argument("--moe-ratio", type=float, default=128.0,
                    help="max dropless/capacity step-time ratio per moe "
                         "dispatch point (loose: the ragged grouped-matmul "
                         "lowering costs ~E dense matmuls)")
    ap.add_argument("--epso-parity", type=float, default=1.15,
                    help="max overlapped-epso / eager-so step-time ratio "
                         "(in-run, so tighter than --tol)")
    ap.add_argument("--epso-vs-none", type=float, default=1.25,
                    help="max overlapped-epso / unsharded step-time ratio")
    ap.add_argument("--kernel-parity", type=float, default=1.05,
                    help="max autotuned/default kernel-time ratio per "
                         "bucket (in-run, so tighter than --tol)")
    ap.add_argument("--census-tol", type=float, default=1.5,
                    help="ring-bytes factor per collective kind for the "
                         "census gate (counts are gated exactly)")
    args = ap.parse_args(argv)

    if args.all:
        return check_all(args)
    if not args.fresh or not args.baseline:
        ap.error("--fresh and --baseline are required (or use --all)")

    fresh, base = _load(args.fresh), _load(args.baseline)
    kind, errors = check_pair(fresh, base, args)
    if kind is None:
        print(f"unrecognized bench JSON shape in {args.fresh}")
        return 2

    if errors:
        print(f"BENCH REGRESSION ({kind}): {len(errors)} violation(s)")
        for e in errors:
            print(" -", e)
        return 1
    print(f"bench gate ok ({kind}): fresh within {args.tol}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
