"""Paper Figure 4: compute scaling of Mula-220B-A10B from 384 to 12288 tiles
(~90 % efficiency), ± FUR.

Without hardware, scaling efficiency is derived from the roofline model the
dry-run produces (spec: derive terms from the compiled artifact):

    eff(n) = t_useful / (t_compute + t_collective(n) + t_bubble)

* per-chip compute time is constant in n (batch scales with chips — the
  paper's weak-scaling setup: DP grows, per-rank work fixed);
* the DP gradient-reduction collective grows with the ring factor
  (n_dp - 1)/n_dp and crosses pods above 256 chips (DCI hop modeled at the
  same per-link bandwidth, 2 hops);
* EP dispatch collectives are intra-node (EP=12 in the paper; fixed);
* PP bubble for Mula-220B: PP=8, microbatches from the 6.3 M-token global
  batch (grows with n => bubble shrinks);
* routed-MoE imbalance (no FUR): per-step time is set by the most-loaded
  expert rank; for multinomial routing the expected max/mean load factor is
  modeled as 1 + c*sqrt(E ln E / T_ep); FUR removes it (paper observes both
  curves track — imbalance is small at these token counts, which this model
  reproduces).

Measured counterpart (``python benchmarks/bench_scaling.py``): a subprocess
with 8 forced CPU host devices runs the *real* jitted pipeline train step
(launch path: (data, pp) mesh + 1f1b/gpipe schedule masks) for
pp in {1, 2, 4}, validates the analytic bubble fraction against the actual
tick tables the executor walks, races the masked-SPMD executor against the
shard_map-per-stage one across vocab sizes (``executor_points``; the
reclaimed head+CE GFLOPs grow with V), and writes ``BENCH_pp.json``.
``--tiny`` is the CI bench-smoke mode (fewer points, median-of-3), gated
against the committed JSON by ``benchmarks/check_regression.py``.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(ROOT, "src") not in sys.path:      # direct-script invocation
    sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.configs import get_config
from repro.launch.roofline import LINK_BW, PEAK_FLOPS

TILES = [384, 768, 1536, 3072, 6144, 12288]
GLOBAL_BATCH_TOKENS = 6.3e6
EP = 12
PP = 8


def efficiency(n_tiles: int, *, fur: bool, cfg) -> float:
    """Weak scaling (paper §2.3: 'with scaling, the batch size increases'):
    per-rank tokens are constant; the global batch grows with tiles."""
    tokens_per_rank = 2048                      # one 2048-token context/rank
    active = cfg.active_param_count()
    # compute: 8*N_active*D per rank-token (fwd+bwd+remat) at ~50% MFU
    t_compute = 8 * active * tokens_per_rank / (PEAK_FLOPS * 0.5)

    # DP gradient reduction: bf16 grads, ring over n_dp ranks; beyond one
    # pod (256 chips in our mapping) the inter-pod stage halves the
    # effective link — the paper's ~10% step when crossing ~1000 tiles
    n_dp = max(n_tiles // (EP * PP // 12), 1)
    grad_bytes = 2 * cfg.param_count() / (EP * PP)   # per-rank shard
    ring = (n_dp - 1) / max(n_dp, 1)
    link = LINK_BW if n_tiles <= 512 else LINK_BW / 2
    t_grad = 2 * grad_bytes * ring / link

    # EP dispatch (Stage 1 allgather + Stage 5 reduce-scatter): intra-node,
    # constant per rank
    t_ep = 2 * tokens_per_rank * cfg.d_model * 2 * (EP - 1) / LINK_BW

    # PP bubble: microbatches per pipeline constant under weak scaling
    n_mb = 16
    bubble = (PP - 1) / (n_mb + PP - 1)

    # MoE imbalance (non-FUR): straggler factor on expert compute
    imb = 1.0
    if not fur:
        T_ep = tokens_per_rank * EP * cfg.moe.experts_per_token
        E = cfg.moe.num_experts
        imb = 1 + 0.5 * math.sqrt(E * math.log(E) / max(T_ep, 1))

    t_step = (t_compute * imb) / (1 - bubble) + t_grad + t_ep
    t_ideal = t_compute / (1 - bubble)
    return t_ideal / t_step


def run(report):
    cfg = get_config("mula-220b-a10b")
    base = {}
    for fur in (False, True):
        effs = [efficiency(n, fur=fur, cfg=cfg) for n in TILES]
        effs = [e / effs[0] for e in effs]      # normalize to 384 tiles
        for n, e in zip(TILES, effs):
            tag = "fur" if fur else "routed"
            report(f"scaling_eff_{tag}[{n}tiles]", e * 100,
                   derived=f"paper~{'90' if n >= 1536 else '97-100'}%")


# ---------------------------------------------------------------------------
# measured: the jitted PP step on a simulated (data, pp, model) mesh
# ---------------------------------------------------------------------------

PP_POINTS = [(1, None), (2, "gpipe"), (2, "1f1b"), (4, "gpipe"), (4, "1f1b")]
PP_POINTS_TINY = [(1, None), (2, "gpipe"), (2, "1f1b")]
# executor comparison: masked vs shardmap at growing vocab sizes — the
# reclaimed head+CE compute grows with V (per-stage FLOP attribution);
# the measured ratio on the sim mesh stays ~1.3-1.4x across V (block
# compute and fixed overheads scale alongside the head)
EXEC_VOCABS = [512, 2048, 8192]
EXEC_VOCABS_TINY = [512, 2048]
N_MB = 8


def _one_point(cfg, tc, host_batch, *, pp, sched, impl, steps, batch):
    import time

    import jax

    from repro.configs import ParallelConfig
    from repro.parallel.plan import ParallelPlan
    from repro.parallel.sharding import batch_sharding
    from repro.train import init_state, make_train_step

    plan = ParallelPlan(dp=8 // pp, pp=pp, opt_shard="epso",
                        pp_schedule=sched or "1f1b", pp_impl=impl,
                        microbatches=N_MB).resolve(cfg, global_batch=batch)
    par = ParallelConfig(microbatches=N_MB, pp_stages=pp,
                         pp_schedule=sched or "1f1b", pp_impl=impl)
    state = init_state(jax.random.PRNGKey(0), cfg, tc, plan=plan)
    step_fn = make_train_step(cfg, par, tc, plan=plan)
    b = jax.tree.map(
        lambda a: jax.device_put(a, batch_sharding(plan.rules)), host_batch)
    state, m = step_fn(state, b)                 # compile + place
    jax.block_until_ready(m["loss"])
    # per-step medians: the forced-host-device simulation shares CPU cores,
    # so a mean over consecutive steps is hostage to scheduler noise
    ts = []
    for _ in range(steps):
        t0 = time.perf_counter()
        state, m = step_fn(state, b)
        jax.block_until_ready(m["loss"])
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return float(m["loss"]), ts[len(ts) // 2] * 1e3


def measure_pp(steps: int = 5, d_model: int = 64, layers: int = 4,
               seq: int = 32, batch: int = 16, tiny: bool = False) -> dict:
    """Runs inside a process whose backend sees 8 devices: time the real
    jitted train step for each PP point, validate the analytic bubble
    fraction against the tick table the executor actually walks, and
    compare the masked vs shard_map-per-stage executors across vocab sizes
    (per-stage FLOP attribution from launch.costmodel.per_stage_costs)."""
    import dataclasses

    import jax

    from repro.configs import TrainConfig, reduced
    from repro.launch.costmodel import per_stage_costs
    from repro.parallel import pipeline as PP

    cfg = reduced(get_config("mula-7b-a1b"), layers=layers, d_model=d_model)
    tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                     grad_reduce_dtype="float32", lr_peak=1e-3, lr_min=1e-4,
                     warmup_steps=2, total_steps=steps + 1, seq_len=seq,
                     global_batch=batch)
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, seq + 1), 0,
                              cfg.vocab_size)
    host_batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # ---- schedule/bubble points (default shardmap executor) ---------------
    points = []
    for pp, sched in (PP_POINTS_TINY if tiny else PP_POINTS):
        loss, ms = _one_point(cfg, tc, host_batch, pp=pp, sched=sched,
                              impl="shardmap", steps=steps, batch=batch)
        entry = {"pp": pp, "schedule": sched, "loss": loss,
                 "step_time_ms": ms,
                 "bubble_analytic": PP.bubble_fraction(N_MB, pp)}
        if pp > 1:
            masks = PP.schedule_masks(sched, N_MB, pp)
            entry["ticks"] = int(masks["ticks"])
            entry["bubble_ticktable"] = 1 - 2 * N_MB / masks["ticks"]
            assert abs(entry["bubble_ticktable"]
                       - entry["bubble_analytic"]) < 1e-9, entry
        points.append(entry)

    # ---- executor comparison across vocab sizes ---------------------------
    # pp=4 throughout: with pp=2 the reclaimable fraction (1 of 2 stages'
    # head) barely clears the executor's fixed overheads on this sim mesh.
    # tiny (CI bench-smoke) measures a prefix of the full matrix, so every
    # tiny point has a committed full-run counterpart to gate against.
    matrix = [(4, v) for v in (EXEC_VOCABS_TINY if tiny else EXEC_VOCABS)]
    exec_points = []
    for exec_pp, vocab in matrix:
        vcfg = dataclasses.replace(cfg, vocab_size=vocab,
                                   name=f"{cfg.name}-v{vocab}")
        vtoks = jax.random.randint(jax.random.PRNGKey(1), (batch, seq + 1),
                                   0, vocab)
        vbatch = {"tokens": vtoks[:, :-1], "labels": vtoks[:, 1:]}
        row = {"vocab": vocab, "pp": exec_pp}
        for impl in ("masked", "shardmap"):
            loss, ms = _one_point(vcfg, tc, vbatch, pp=exec_pp, sched="1f1b",
                                  impl=impl, steps=steps, batch=batch)
            psc = per_stage_costs(vcfg, pp=exec_pp, microbatches=N_MB,
                                  seq=seq, global_batch=batch, pp_impl=impl)
            row[impl] = {
                "loss": loss, "step_time_ms": ms,
                "per_stage_gflops": [s["total_gflops"]
                                     for s in psc["stages"]],
                "head_gflops": [s["head_gflops"] for s in psc["stages"]],
            }
        row["speedup"] = (row["masked"]["step_time_ms"]
                          / row["shardmap"]["step_time_ms"])
        row["head_gflops_reclaimed"] = (
            sum(row["masked"]["head_gflops"])
            - sum(row["shardmap"]["head_gflops"]))
        exec_points.append(row)

    return {"arch": cfg.name, "d_model": d_model, "layers": layers,
            "seq": seq, "batch": batch, "microbatches": N_MB,
            "devices": len(jax.devices()), "tiny": tiny, "points": points,
            "executor_points": exec_points}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--tiny", action="store_true",
                    help="CI bench-smoke mode: fewer points, 2 steps")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_pp.json"))
    ap.add_argument("--_measure", action="store_true",
                    help=argparse.SUPPRESS)   # child-process mode
    args = ap.parse_args(argv)
    if args.tiny:
        args.steps = min(args.steps, 3)   # median-of-3 in CI smoke

    if args._measure:
        print(json.dumps(measure_pp(steps=args.steps, tiny=args.tiny)))
        return

    from repro.launch.mesh import forced_device_env
    env = forced_device_env(8)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--_measure",
         "--steps", str(args.steps)] + (["--tiny"] if args.tiny else []),
        capture_output=True, text=True, env=env, timeout=3600)
    if r.returncode != 0:
        sys.stderr.write(r.stdout + r.stderr)
        raise SystemExit("bench_scaling measured PP run failed")
    result = json.loads(r.stdout.strip().splitlines()[-1])
    # every pp>1 point computes the same math, but each runs on a different
    # mesh (different data-axis reduction orders), so cross-point agreement
    # is only guaranteed to ~1 ulp — not bit-for-bit. The pp=1 point is
    # excluded: the non-PP step's MoE capacity aligns to the batch-axis
    # size (c_align=dp) while PP stages always run the c_align=1
    # dense-capacity path (see train/trainer.py), so its loss may differ
    # legitimately at batch shapes where the capacity rounding diverges.
    # (moe_dispatch='dropless' removes that divergence — the pools become
    # routing-independent — but this bench keeps the paper-default
    # capacity dispatch.)
    pp_pts = [p for p in result["points"] if p["pp"] > 1]
    base = pp_pts[0]["loss"]
    for p in pp_pts:
        assert abs(p["loss"] - base) < 1e-5 * abs(base), pp_pts
    # the two executors must agree on the loss at every vocab point
    for row in result["executor_points"]:
        lm, ls = row["masked"]["loss"], row["shardmap"]["loss"]
        assert abs(lm - ls) < 1e-5 * abs(lm), row
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    for p in result["points"]:
        sched = p["schedule"] or "-"
        tick = p.get("bubble_ticktable")
        print(f"pp={p['pp']} {sched:6s} step={p['step_time_ms']:7.1f}ms "
              f"bubble={p['bubble_analytic']:.3f}"
              + (f" (ticktable {tick:.3f})" if tick is not None else ""))
    for row in result["executor_points"]:
        print(f"vocab={row['vocab']:6d} pp={row['pp']} "
              f"masked={row['masked']['step_time_ms']:7.1f}ms "
              f"shardmap={row['shardmap']['step_time_ms']:7.1f}ms "
              f"speedup={row['speedup']:.2f}x "
              f"(head GF reclaimed {row['head_gflops_reclaimed']:.2f})")
    biggest = result["executor_points"][-1]
    if biggest["speedup"] <= 1.0:
        print("WARNING: per-stage executor not faster at the largest "
              "vocab — investigate before committing this JSON")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
