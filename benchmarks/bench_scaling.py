"""Paper Figure 4: compute scaling of Mula-220B-A10B from 384 to 12288 tiles
(~90 % efficiency), ± FUR.

Without hardware, scaling efficiency is derived from the roofline model the
dry-run produces (spec: derive terms from the compiled artifact):

    eff(n) = t_useful / (t_compute + t_collective(n) + t_bubble)

* per-chip compute time is constant in n (batch scales with chips — the
  paper's weak-scaling setup: DP grows, per-rank work fixed);
* the DP gradient-reduction collective grows with the ring factor
  (n_dp - 1)/n_dp and crosses pods above 256 chips (DCI hop modeled at the
  same per-link bandwidth, 2 hops);
* EP dispatch collectives are intra-node (EP=12 in the paper; fixed);
* PP bubble for Mula-220B: PP=8, microbatches from the 6.3 M-token global
  batch (grows with n => bubble shrinks);
* routed-MoE imbalance (no FUR): per-step time is set by the most-loaded
  expert rank; for multinomial routing the expected max/mean load factor is
  modeled as 1 + c*sqrt(E ln E / T_ep); FUR removes it (paper observes both
  curves track — imbalance is small at these token counts, which this model
  reproduces).
"""
from __future__ import annotations

import math

import numpy as np

from repro.configs import get_config
from repro.launch.roofline import LINK_BW, PEAK_FLOPS

TILES = [384, 768, 1536, 3072, 6144, 12288]
GLOBAL_BATCH_TOKENS = 6.3e6
EP = 12
PP = 8


def efficiency(n_tiles: int, *, fur: bool, cfg) -> float:
    """Weak scaling (paper §2.3: 'with scaling, the batch size increases'):
    per-rank tokens are constant; the global batch grows with tiles."""
    tokens_per_rank = 2048                      # one 2048-token context/rank
    active = cfg.active_param_count()
    # compute: 8*N_active*D per rank-token (fwd+bwd+remat) at ~50% MFU
    t_compute = 8 * active * tokens_per_rank / (PEAK_FLOPS * 0.5)

    # DP gradient reduction: bf16 grads, ring over n_dp ranks; beyond one
    # pod (256 chips in our mapping) the inter-pod stage halves the
    # effective link — the paper's ~10% step when crossing ~1000 tiles
    n_dp = max(n_tiles // (EP * PP // 12), 1)
    grad_bytes = 2 * cfg.param_count() / (EP * PP)   # per-rank shard
    ring = (n_dp - 1) / max(n_dp, 1)
    link = LINK_BW if n_tiles <= 512 else LINK_BW / 2
    t_grad = 2 * grad_bytes * ring / link

    # EP dispatch (Stage 1 allgather + Stage 5 reduce-scatter): intra-node,
    # constant per rank
    t_ep = 2 * tokens_per_rank * cfg.d_model * 2 * (EP - 1) / LINK_BW

    # PP bubble: microbatches per pipeline constant under weak scaling
    n_mb = 16
    bubble = (PP - 1) / (n_mb + PP - 1)

    # MoE imbalance (non-FUR): straggler factor on expert compute
    imb = 1.0
    if not fur:
        T_ep = tokens_per_rank * EP * cfg.moe.experts_per_token
        E = cfg.moe.num_experts
        imb = 1 + 0.5 * math.sqrt(E * math.log(E) / max(T_ep, 1))

    t_step = (t_compute * imb) / (1 - bubble) + t_grad + t_ep
    t_ideal = t_compute / (1 - bubble)
    return t_ideal / t_step


def run(report):
    cfg = get_config("mula-220b-a10b")
    base = {}
    for fur in (False, True):
        effs = [efficiency(n, fur=fur, cfg=cfg) for n in TILES]
        effs = [e / effs[0] for e in effs]      # normalize to 384 tiles
        for n, e in zip(TILES, effs):
            tag = "fur" if fur else "routed"
            report(f"scaling_eff_{tag}[{n}tiles]", e * 100,
                   derived=f"paper~{'90' if n >= 1536 else '97-100'}%")
