# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   Table 3 / FSMOE column  -> bench_fsmoe      (naive vs optimized MoE, F+B)
#   Table 3 / EPSO column   -> bench_epso       (SO vs EPSO state bytes)
#   Figure 4 (scaling)      -> bench_scaling    (roofline-model efficiency)
#   Figure 1 (loss curves)  -> bench_loss       (dense vs MoE iso-compute)
#   kernels (Stage 2/4/5)   -> bench_kernels    (VMEM budgets + validation)
#
# Roofline tables (EXPERIMENTS §Dry-run/§Roofline) are produced by the
# dry-run sweep: PYTHONPATH=src python -m repro.launch.sweep
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of bench names (fsmoe epso scaling loss kernels)")
    args = ap.parse_args()

    from repro import compat as _compat  # noqa: F401  old-jax shims

    from . import (bench_epso, bench_fsmoe, bench_kernels, bench_loss,
                   bench_scaling, bench_serve)
    benches = {"kernels": bench_kernels, "epso": bench_epso,
               "scaling": bench_scaling, "fsmoe": bench_fsmoe,
               "loss": bench_loss, "serve": bench_serve}
    if args.only:
        benches = {k: v for k, v in benches.items() if k in args.only}

    print("name,us_per_call,derived")
    failures = []

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    for name, mod in benches.items():
        try:
            mod.run(report)
        except Exception as e:
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
