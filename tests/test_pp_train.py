"""Jitted pipeline-parallel training path (paper §2.2: Mula-100B PP=4,
Mula-220B PP=8, 1f1b).

Two executors share the tick tables and dataflow (``parallel.pipeline``):
the legacy masked-SPMD ``pipelined_loss_and_grads`` must reproduce the
non-PP train step exactly — same loss, same updated params — because the
schedule only reorders independent work and gradient accumulation stays in
microbatch order (the acc_step contract); the shard_map-per-stage
``pipelined_loss_and_grads_per_stage`` (pp_impl='shardmap', the on-mesh
default) must bit-match the masked executor's loss and agree on grads to
~1 ulp (golden parity test below). Off-mesh, pp_impl='shardmap' falls back
to the masked executor, which is what the single-device tests exercise.
"""
import jax
import numpy as np
import pytest

from repro.configs import ParallelConfig, TrainConfig, get_config, reduced
from repro.train import init_state, make_train_step


def _tc(seq=16, batch=8):
    return TrainConfig(param_dtype="float32", compute_dtype="float32",
                       grad_reduce_dtype="float32", lr_peak=1e-3,
                       lr_min=1e-4, warmup_steps=2, total_steps=10,
                       seq_len=seq, global_batch=batch)


def _batch(cfg, batch=8, seq=16, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (batch, seq + 1), 0,
                              cfg.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@pytest.mark.parametrize("arch,at", [("mula-7b-a1b", "moe"),
                                     ("mula-1b", "dense")])
@pytest.mark.parametrize("sched", ["1f1b", "gpipe"])
def test_pp_step_bit_matches_non_pp_single_device(arch, at, sched):
    """pp_stages=2 through the jitted executor == the plain microbatch-
    accumulation step, bit-for-bit (single device: identical op order)."""
    cfg = reduced(get_config(arch), layers=2, d_model=32)
    assert cfg.arch_type == at
    tc = _tc()
    state = init_state(jax.random.PRNGKey(0), cfg, tc)
    batch = _batch(cfg)
    s_ref, m_ref = jax.jit(make_train_step(
        cfg, ParallelConfig(microbatches=4), tc))(state, batch)
    s_pp, m_pp = jax.jit(make_train_step(
        cfg, ParallelConfig(microbatches=4, pp_stages=2, pp_schedule=sched),
        tc))(state, batch)
    assert float(m_ref["loss"]) == float(m_pp["loss"])
    assert float(m_ref["ce"]) == float(m_pp["ce"])
    for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s_pp.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pp1_falls_back_to_plain_step():
    """pp_stages=1 ignores pp_impl/pp_schedule entirely: the step is the
    plain microbatch-accumulation path, bit-for-bit."""
    cfg = reduced(get_config("mula-1b"), layers=2, d_model=32)
    tc = _tc()
    state = init_state(jax.random.PRNGKey(0), cfg, tc)
    batch = _batch(cfg)
    s_ref, m_ref = jax.jit(make_train_step(
        cfg, ParallelConfig(microbatches=4), tc))(state, batch)
    s_pp1, m_pp1 = jax.jit(make_train_step(
        cfg, ParallelConfig(microbatches=4, pp_stages=1,
                            pp_schedule="gpipe", pp_impl="shardmap"),
        tc))(state, batch)
    assert float(m_ref["loss"]) == float(m_pp1["loss"])
    for a, b in zip(jax.tree.leaves(s_ref.params),
                    jax.tree.leaves(s_pp1.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pp_shardmap_rejects_indivisible_microbatches():
    """The per-stage executor's wave-balance guardrail surfaces at build
    time with a descriptive error (mesh is shape-only — no devices)."""
    from repro.compat import AxisType
    from jax.sharding import AbstractMesh

    cfg = reduced(get_config("mula-1b"), layers=2, d_model=32)
    mesh = AbstractMesh((2, 2), ("data", "pp"),
                        axis_types=(AxisType.Auto,) * 2)
    # mesh= is the deprecated legacy threading — this test doubles as the
    # pinned DeprecationWarning check (an AbstractMesh has no device pool,
    # so it cannot ride a resolved plan)
    with pytest.warns(DeprecationWarning, match="plan="):
        with pytest.raises(ValueError, match="divisible by pp_stages"):
            make_train_step(cfg, ParallelConfig(microbatches=3, pp_stages=2,
                                                pp_impl="shardmap"),
                            _tc(), mesh=mesh)
    # the masked executor keeps accepting any n_mb >= 1
    with pytest.warns(DeprecationWarning, match="plan="):
        make_train_step(cfg, ParallelConfig(microbatches=3, pp_stages=2,
                                            pp_impl="masked"),
                        _tc(), mesh=mesh)


def test_pp_step_rejects_non_uniform_arch():
    cfg = reduced(get_config("zamba2-7b"), layers=4, d_model=32)   # hybrid
    with pytest.raises(ValueError, match="arch_type"):
        make_train_step(cfg, ParallelConfig(pp_stages=2), _tc())


def test_pp_step_rejects_indivisible_layers():
    cfg = reduced(get_config("mula-1b"), layers=3, d_model=32)
    step = jax.jit(make_train_step(
        cfg, ParallelConfig(microbatches=4, pp_stages=2), _tc()))
    state = init_state(jax.random.PRNGKey(0), cfg, _tc())
    with pytest.raises(ValueError, match="pp_stages=2"):
        step(state, _batch(cfg))


# ---------------------------------------------------------------------------
# 8-device sim mesh: PP x EP x DP x EPSO composition (paper's real layout)
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.slow
def test_jitted_1f1b_grads_match_single_stage_on_mesh8(mesh8):
    """(data=2, pp=2, model=2) mesh, EPSO state placement: the jitted 1f1b
    *masked* executor's loss and updated params equal the non-PP
    single-device step on the same batch (pp_impl='masked' is the executor
    whose single-program structure makes that bit-parity hold); the layer
    stack is stage-sharded over 'pp'."""
    out = mesh8("""
        import jax, numpy as np
        from repro.configs import get_config, reduced, TrainConfig, ParallelConfig
        from repro.train import init_state, make_train_step, train_state_shardings
        from repro.parallel.plan import ParallelPlan
        from repro.parallel.sharding import batch_sharding

        cfg = reduced(get_config("mula-7b-a1b"), layers=2, d_model=64)
        tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                         grad_reduce_dtype="float32", lr_peak=1e-3,
                         lr_min=1e-4, warmup_steps=2, total_steps=10,
                         seq_len=32, global_batch=8)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

        state0 = init_state(jax.random.PRNGKey(0), cfg, tc)
        s1, m1 = jax.jit(make_train_step(
            cfg, ParallelConfig(microbatches=4), tc))(state0, batch)

        plan = ParallelPlan.from_legacy("2,2,2", cfg=cfg, opt_shard="epso") \
            .resolve(cfg, global_batch=8)
        rules = plan.rules
        assert rules.pp_axis == "pp", rules
        state = init_state(jax.random.PRNGKey(0), cfg, tc, plan=plan)
        wq = state.params["layers"]["attn"]["wq"]
        assert tuple(wq.sharding.spec) == ("pp", None, None), wq.sharding
        ssh = train_state_shardings(state.params, rules, "epso")
        step = make_train_step(
            cfg, ParallelConfig(microbatches=4, pp_stages=2,
                                pp_schedule="1f1b", pp_impl="masked"),
            tc, plan=plan, state_shardings=ssh)
        bsh = batch_sharding(rules)
        bdev = jax.tree.map(lambda a: jax.device_put(a, bsh), batch)
        s2, m2 = step(state, bdev)
        assert float(m1["loss"]) == float(m2["loss"]), (m1["loss"], m2["loss"])
        for a, b in zip(jax.tree.leaves(s1.params),
                        jax.tree.leaves(s2.params)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print("PP-MESH-PARITY-OK")
    """, timeout=1200)
    assert "PP-MESH-PARITY-OK" in out


@pytest.mark.distributed
@pytest.mark.slow
def test_shardmap_executor_golden_parity_mesh8(mesh8):
    """Golden parity between the two pipeline executors on the paper-shaped
    (data=2, pp=2, model=2) mesh with EPSO state placement.

    The shard_map-per-stage executor runs a *different program* per stage
    (only stage 0 embeds, only the last stage runs head+CE), so the loss
    scalars — produced by the identical forward math — must bit-match the
    masked executor. Gradients agree to ~1 ulp: XLA fuses the
    head->blocks backward chain differently once the vjp is factored at
    the stage-output boundary, which reassociates a handful of f32 sums
    (measured drift <= a few 1e-9 absolute on unit-scale grads; the seed
    bug class this test exists to catch shows up at 1e-1). Updated params
    are compared at that ulp-scale tolerance and usually match exactly."""
    out = mesh8("""
        import jax, numpy as np
        from repro.configs import get_config, reduced, TrainConfig, ParallelConfig
        from repro.train import init_state, make_train_step, train_state_shardings
        from repro.parallel.plan import ParallelPlan
        from repro.parallel.sharding import batch_sharding

        cfg = reduced(get_config("mula-7b-a1b"), layers=2, d_model=64)
        tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                         grad_reduce_dtype="float32", lr_peak=1e-3,
                         lr_min=1e-4, warmup_steps=2, total_steps=10,
                         seq_len=32, global_batch=8)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

        plan = ParallelPlan.from_legacy("2,2,2", cfg=cfg, opt_shard="epso") \
            .resolve(cfg, global_batch=8)
        rules = plan.rules
        state = init_state(jax.random.PRNGKey(0), cfg, tc, plan=plan)
        ssh = train_state_shardings(state.params, rules, "epso")
        bsh = batch_sharding(rules)
        bdev = jax.tree.map(lambda a: jax.device_put(a, bsh), batch)

        outs = {}
        for impl in ("masked", "shardmap"):
            step = make_train_step(
                cfg, ParallelConfig(microbatches=4, pp_stages=2,
                                    pp_schedule="1f1b", pp_impl=impl),
                tc, plan=plan, state_shardings=ssh)
            outs[impl] = step(state, bdev)
        (s_m, m_m), (s_s, m_s) = outs["masked"], outs["shardmap"]
        # loss scalars: identical forward math => bit-equal
        assert float(m_m["loss"]) == float(m_s["loss"]), (m_m, m_s)
        assert float(m_m["ce"]) == float(m_s["ce"]), (m_m, m_s)
        # updated params: ulp-scale tolerance (see test docstring)
        for a, b in zip(jax.tree.leaves(s_m.params),
                        jax.tree.leaves(s_s.params)):
            a = np.asarray(a, np.float64)
            b = np.asarray(b, np.float64)
            assert np.allclose(a, b, rtol=2e-5, atol=1e-7), \
                float(np.abs(a - b).max())
        print("SHARDMAP-GOLDEN-PARITY-OK")
    """, timeout=1800)
    assert "SHARDMAP-GOLDEN-PARITY-OK" in out
