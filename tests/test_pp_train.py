"""Jitted pipeline-parallel training path (paper §2.2: Mula-100B PP=4,
Mula-220B PP=8, 1f1b): the mesh-native executor in
``parallel.pipeline.pipelined_loss_and_grads`` must reproduce the non-PP
train step exactly — same loss, same updated params — because the schedule
only reorders independent work and gradient accumulation stays in microbatch
order (the acc_step contract).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ParallelConfig, TrainConfig, get_config, reduced
from repro.parallel import pipeline as PP
from repro.train import init_state, make_train_step


def _tc(seq=16, batch=8):
    return TrainConfig(param_dtype="float32", compute_dtype="float32",
                       grad_reduce_dtype="float32", lr_peak=1e-3,
                       lr_min=1e-4, warmup_steps=2, total_steps=10,
                       seq_len=seq, global_batch=batch)


def _batch(cfg, batch=8, seq=16, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (batch, seq + 1), 0,
                              cfg.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@pytest.mark.parametrize("arch,at", [("mula-7b-a1b", "moe"),
                                     ("mula-1b", "dense")])
@pytest.mark.parametrize("sched", ["1f1b", "gpipe"])
def test_pp_step_bit_matches_non_pp_single_device(arch, at, sched):
    """pp_stages=2 through the jitted executor == the plain microbatch-
    accumulation step, bit-for-bit (single device: identical op order)."""
    cfg = reduced(get_config(arch), layers=2, d_model=32)
    assert cfg.arch_type == at
    tc = _tc()
    state = init_state(jax.random.PRNGKey(0), cfg, tc)
    batch = _batch(cfg)
    s_ref, m_ref = jax.jit(make_train_step(
        cfg, ParallelConfig(microbatches=4), tc))(state, batch)
    s_pp, m_pp = jax.jit(make_train_step(
        cfg, ParallelConfig(microbatches=4, pp_stages=2, pp_schedule=sched),
        tc))(state, batch)
    assert float(m_ref["loss"]) == float(m_pp["loss"])
    assert float(m_ref["ce"]) == float(m_pp["ce"])
    for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s_pp.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pp_step_rejects_non_uniform_arch():
    cfg = reduced(get_config("zamba2-7b"), layers=4, d_model=32)   # hybrid
    with pytest.raises(ValueError, match="arch_type"):
        make_train_step(cfg, ParallelConfig(pp_stages=2), _tc())


def test_pp_step_rejects_indivisible_layers():
    cfg = reduced(get_config("mula-1b"), layers=3, d_model=32)
    step = jax.jit(make_train_step(
        cfg, ParallelConfig(microbatches=4, pp_stages=2), _tc()))
    state = init_state(jax.random.PRNGKey(0), cfg, _tc())
    with pytest.raises(ValueError, match="pp_stages=2"):
        step(state, _batch(cfg))


# ---------------------------------------------------------------------------
# 8-device sim mesh: PP x EP x DP x EPSO composition (paper's real layout)
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.slow
def test_jitted_1f1b_grads_match_single_stage_on_mesh8(mesh8):
    """(data=2, pp=2, model=2) mesh, EPSO state placement: the jitted 1f1b
    step's loss and updated params equal the non-PP single-device step on
    the same batch; the layer stack is stage-sharded over 'pp'."""
    out = mesh8("""
        import jax, numpy as np
        from repro.configs import get_config, reduced, TrainConfig, ParallelConfig
        from repro.train import init_state, make_train_step, train_state_shardings
        from repro.parallel.sharding import make_rules, batch_sharding
        from repro.launch.mesh import make_sim_mesh

        mesh = make_sim_mesh("2,2,2")
        cfg = reduced(get_config("mula-7b-a1b"), layers=2, d_model=64)
        tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                         grad_reduce_dtype="float32", lr_peak=1e-3,
                         lr_min=1e-4, warmup_steps=2, total_steps=10,
                         seq_len=32, global_batch=8)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

        state0 = init_state(jax.random.PRNGKey(0), cfg, tc)
        s1, m1 = jax.jit(make_train_step(
            cfg, ParallelConfig(microbatches=4), tc))(state0, batch)

        rules = make_rules(cfg, mesh, kind="train", global_batch=8)
        assert rules.pp_axis == "pp", rules
        state = init_state(jax.random.PRNGKey(0), cfg, tc, rules=rules,
                           opt_sharding_mode="epso")
        wq = state.params["layers"]["attn"]["wq"]
        assert tuple(wq.sharding.spec) == ("pp", None, None), wq.sharding
        ssh = train_state_shardings(state.params, rules, "epso")
        step = make_train_step(
            cfg, ParallelConfig(microbatches=4, pp_stages=2,
                                pp_schedule="1f1b"),
            tc, rules=rules, mesh=mesh, opt_sharding_mode="epso",
            state_shardings=ssh)
        bsh = batch_sharding(rules)
        bdev = jax.tree.map(lambda a: jax.device_put(a, bsh), batch)
        s2, m2 = step(state, bdev)
        assert float(m1["loss"]) == float(m2["loss"]), (m1["loss"], m2["loss"])
        for a, b in zip(jax.tree.leaves(s1.params),
                        jax.tree.leaves(s2.params)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print("PP-MESH-PARITY-OK")
    """, timeout=1200)
    assert "PP-MESH-PARITY-OK" in out
