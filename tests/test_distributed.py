"""Distributed semantics tests — run in a subprocess with 8 forced host
devices (the ``mesh8`` conftest fixture) so the main pytest process keeps
its single-device view."""
import pytest

pytestmark = pytest.mark.distributed


def test_parse_mesh_spec():
    from repro.launch.mesh import parse_mesh_spec
    assert parse_mesh_spec("8") == ((8,), ("data",))
    assert parse_mesh_spec("4,2") == ((4, 2), ("data", "model"))
    assert parse_mesh_spec("2,2,2") == ((2, 2, 2), ("data", "pp", "model"))
    assert parse_mesh_spec("2,2,2,2") == ((2, 2, 2, 2),
                                          ("pod", "data", "pp", "model"))
    with pytest.raises(ValueError):
        parse_mesh_spec("1,2,3,4,5")
    with pytest.raises(ValueError):
        parse_mesh_spec("")


@pytest.mark.slow
def test_fsmoe_ep_matches_naive_with_grads(mesh8):
    """Paper Algorithm 1 under a real 2x4 (data, model) mesh: forward and
    gradients equal the naive single-device reference; the collective
    schedule contains Stage-1 all-gather + Stage-5 reduce-scatter and no
    all-to-all."""
    out = mesh8("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType
        from repro.configs.base import ModelConfig, MoEConfig
        from repro.core import moe as M
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        cfg = ModelConfig(name="t", arch_type="moe", num_layers=1, d_model=32,
                          num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64,
                          moe=MoEConfig(num_experts=8, experts_per_token=2,
                                        d_ff_expert=16, capacity_factor=4.0,
                                        moe_impl="fsmoe"))
        p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        ref, _ = M.moe_naive(p, x, cfg.moe)
        pspec = {"router": P(), "gate": P("model", None, None),
                 "up": P("model", None, None), "down": P("model", None, None)}
        ps = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                          p, pspec)
        xs = jax.device_put(x, NamedSharding(mesh, P(("data", "model"), None)))
        def f(p, x):
            out, r, stats = M.moe_fsmoe_ep(p, x, cfg.moe, mesh=mesh)
            return out
        out = jax.jit(f)(ps, xs)
        assert np.allclose(ref, out, atol=1e-4), "forward mismatch"
        g1 = jax.jit(jax.grad(lambda p, x: (f(p, x)**2).sum()))(ps, xs)
        g2 = jax.grad(lambda p: (M.moe_naive(p, x, cfg.moe)[0]**2).sum())(p)
        for k in ("router", "gate", "up", "down"):
            assert np.allclose(g1[k], g2[k], atol=1e-3), k
        txt = jax.jit(f).lower(ps, xs).compile().as_text()
        assert "all-gather" in txt and "reduce-scatter" in txt
        assert "all-to-all" not in txt
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_fsmoe_a2a_dispatch_matches_naive(mesh8):
    """Beyond-paper Stage-1 variant (EXPERIMENTS §Perf): capacity-bounded
    all-to-all dispatch is numerically identical to the allgather path and
    the naive reference in the dropless regime."""
    out = mesh8("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType
        from repro.configs.base import ModelConfig, MoEConfig
        from repro.core import moe as M
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        cfg = ModelConfig(name="t", arch_type="moe", num_layers=1, d_model=32,
                          num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64,
                          moe=MoEConfig(num_experts=8, experts_per_token=2,
                                        d_ff_expert=16, capacity_factor=8.0,
                                        moe_impl="fsmoe", stage1="a2a"))
        p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        ref, _ = M.moe_naive(p, x, cfg.moe)
        pspec = {"router": P(), "gate": P("model", None, None),
                 "up": P("model", None, None), "down": P("model", None, None)}
        ps = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                          p, pspec)
        xs = jax.device_put(x, NamedSharding(mesh, P(("data", "model"), None)))
        def f(p, x):
            out, r, stats = M.moe_fsmoe_ep(p, x, cfg.moe, mesh=mesh)
            return out, stats
        out, stats = jax.jit(f)(ps, xs)
        assert int(stats.drops) == 0
        assert int(stats.counts.sum()) > 0
        assert np.allclose(ref, out, atol=1e-4)
        g1 = jax.jit(jax.grad(lambda p, x: (f(p, x)[0]**2).sum()))(ps, xs)
        g2 = jax.grad(lambda p: (M.moe_naive(p, x, cfg.moe)[0]**2).sum())(p)
        for k in ("router", "gate", "up", "down"):
            assert np.allclose(g1[k], g2[k], atol=1e-3), k
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_moe_etp_shard_map_matches_naive(mesh8):
    """Beyond-paper ETP path (mixtral hillclimb): local dispatch + one psum
    over the model axis; exact vs the naive reference."""
    out = mesh8("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType
        from repro.configs.base import ModelConfig, MoEConfig
        from repro.core import moe as M
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        cfg = ModelConfig(name="t", arch_type="moe", num_layers=1, d_model=32,
                          num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64,
                          moe=MoEConfig(num_experts=2, experts_per_token=1,
                                        d_ff_expert=16, capacity_factor=2.0,
                                        moe_impl="fsmoe", etp_shard_map=True))
        p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        ref, _ = M.moe_naive(p, x, cfg.moe)
        pspec = {"router": P(), "gate": P(None, None, "model"),
                 "up": P(None, None, "model"), "down": P(None, "model", None)}
        ps = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                          p, pspec)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        def f(p, x):
            out, r, stats = M.moe_etp_shard_map(p, x, cfg.moe, mesh=mesh,
                                                batch_axes=("data",))
            return out
        out = jax.jit(f)(ps, xs)
        assert np.allclose(ref, out, atol=1e-4)
        g1 = jax.jit(jax.grad(lambda p, x: (f(p, x)**2).sum()))(ps, xs)
        g2 = jax.grad(lambda p: (M.moe_naive(p, x, cfg.moe)[0]**2).sum())(p)
        for k in ("router", "gate", "up", "down"):
            assert np.allclose(g1[k], g2[k], atol=1e-3), k
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_train_step_matches_single_device(mesh8):
    """pjit train_step on a (2,4) mesh == single-device train_step."""
    out = mesh8("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced, TrainConfig, ParallelConfig
        from repro.train import init_state, make_train_step
        from repro.parallel.plan import ParallelPlan
        from repro.parallel.sharding import shardings
        from repro.optim.epso import optimizer_state_shardings

        cfg = reduced(get_config("deepseek-7b"), d_model=64)
        tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                         grad_reduce_dtype="float32", warmup_steps=2,
                         total_steps=10, lr_peak=1e-3, lr_min=1e-4)
        state = init_state(jax.random.PRNGKey(0), cfg, tc)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

        s1, m1 = jax.jit(make_train_step(cfg, ParallelConfig(), tc))(state,
                                                                     batch)

        plan = ParallelPlan.from_legacy("2,4", cfg=cfg, opt_shard="epso") \
            .resolve(cfg, global_batch=8)
        rules, mesh = plan.rules, plan.mesh
        psh = shardings(state.params, rules)
        osh = optimizer_state_shardings(state.params, rules, "epso")
        sp = state._replace(
            params=jax.tree.map(jax.device_put, state.params, psh),
            opt=state.opt._replace(
                master=jax.tree.map(jax.device_put, state.opt.master, osh),
                m=jax.tree.map(jax.device_put, state.opt.m, osh),
                v=jax.tree.map(jax.device_put, state.opt.v, osh)))
        bsh = NamedSharding(mesh, P("data", None))
        bp = jax.tree.map(lambda a: jax.device_put(a, bsh), batch)
        step2 = make_train_step(cfg, ParallelConfig(), tc, plan=plan)
        s2, m2 = step2(sp, bp)
        assert np.allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
            assert np.allclose(np.asarray(a), np.asarray(b), atol=2e-4)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_epso_state_placement_on_devices(mesh8):
    """EPSO states occupy fewer bytes per device than SO on a real mesh."""
    out = mesh8("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import AxisType
        from repro.configs import get_config, reduced
        from repro.models import init_params
        from repro.optim import adamw_init
        from repro.optim.epso import optimizer_state_shardings
        from repro.parallel.sharding import make_rules
        import dataclasses
        cfg = reduced(get_config("mixtral-8x7b"), d_model=128, max_experts=4)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                               num_experts=4))
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        rules = make_rules(cfg, mesh, kind="train", global_batch=8)
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        sizes = {}
        for mode in ("so", "epso"):
            sh = optimizer_state_shardings(params, rules, mode)
            placed = jax.tree.map(jax.device_put, opt.m, sh)
            dev0 = jax.devices()[0]
            per_dev = sum(sum(s.data.nbytes for s in l.addressable_shards
                              if s.device == dev0)
                          for l in jax.tree.leaves(placed))
            sizes[mode] = per_dev
        assert sizes["epso"] < sizes["so"], sizes
        print("OK", sizes)
    """)
    assert "OK" in out
