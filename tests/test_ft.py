"""Fault tolerance (paper §4): NaN (soft) detection, buffer-node replacement
(hard), and end-to-end recovery through the dual checkpointer."""
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.ft import (ClusterManager, NaNMonitor, NodeFailure,
                      run_with_failure_handling)


def test_nan_monitor_flags_rank():
    mon = NaNMonitor()
    mon.check([1.0, 2.0, 0.5])                      # fine
    with pytest.raises(NodeFailure) as e:
        mon.check([1.0, float("nan"), 0.5])
    assert e.value.node_id == 1 and e.value.kind == "soft"
    with pytest.raises(NodeFailure):
        mon.check([1.0, 1.0], per_rank_grad_norms=[1.0, float("inf")])


def test_cluster_replace_uses_buffers():
    cm = ClusterManager(n_active=4, n_buffer=2)
    repl = cm.replace(2)
    assert repl.node_id == 4
    assert [n.node_id for n in cm.active] == [0, 1, 4, 3]
    cm.replace(0)
    assert not cm.buffers
    with pytest.raises(RuntimeError):
        cm.replace(1)                                # buffers exhausted


def test_run_recovers_from_soft_and_hard_failures(tmp_path):
    """Full launcher loop: a hard failure at step 7 and a soft (NaN) at
    step 12 are both recovered via buffer nodes + last valid checkpoint."""
    ck = Checkpointer(str(tmp_path), interval=5)
    cluster = ClusterManager(n_active=4, n_buffer=2)
    calls = {"hard_done": False, "soft_done": False}

    def train_one_step(state, step):
        if step == 7 and not calls["hard_done"]:
            calls["hard_done"] = True
            raise NodeFailure(3, "hard")
        if step == 12 and not calls["soft_done"]:
            calls["soft_done"] = True
            return state, {"per_rank_losses": [1.0, float("nan")]}
        new = {"p": {"w": np.asarray(state["p"]["w"]) + 1.0}}
        return new, {"loss": 1.0, "per_rank_losses": [1.0, 1.0]}

    state0 = {"p": {"w": np.zeros(2)}}
    state, step, relaunches = run_with_failure_handling(
        train_one_step, state=state0, checkpointer=ck, cluster=cluster,
        num_steps=20)
    assert step == 20
    assert relaunches == 2
    assert len(cluster.replaced) == 2
    # soft failure consumed a NaN step but training still completed
    assert calls["hard_done"] and calls["soft_done"]
