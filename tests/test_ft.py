"""Fault tolerance (paper §4): NaN (soft) detection, buffer-node replacement
(hard), and end-to-end recovery through the dual checkpointer."""
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.ft import (ClusterManager, NaNMonitor, NodeFailure,
                      run_with_failure_handling)


def test_nan_monitor_flags_rank():
    mon = NaNMonitor()
    mon.check([1.0, 2.0, 0.5])                      # fine
    with pytest.raises(NodeFailure) as e:
        mon.check([1.0, float("nan"), 0.5])
    assert e.value.node_id == 1 and e.value.kind == "soft"
    with pytest.raises(NodeFailure):
        mon.check([1.0, 1.0], per_rank_grad_norms=[1.0, float("inf")])


def test_cluster_replace_uses_buffers():
    cm = ClusterManager(n_active=4, n_buffer=2)
    repl = cm.replace(2)
    assert repl.node_id == 4
    assert [n.node_id for n in cm.active] == [0, 1, 4, 3]
    cm.replace(0)
    assert not cm.buffers
    with pytest.raises(RuntimeError):
        cm.replace(1)                                # buffers exhausted


def test_run_recovers_from_soft_and_hard_failures(tmp_path):
    """Full launcher loop: a hard failure at step 7 and a soft (NaN) at
    step 12 are both recovered via buffer nodes + last valid checkpoint."""
    ck = Checkpointer(str(tmp_path), interval=5)
    cluster = ClusterManager(n_active=4, n_buffer=2)
    calls = {"hard_done": False, "soft_done": False}

    def train_one_step(state, step):
        if step == 7 and not calls["hard_done"]:
            calls["hard_done"] = True
            raise NodeFailure(3, "hard")
        if step == 12 and not calls["soft_done"]:
            calls["soft_done"] = True
            return state, {"per_rank_losses": [1.0, float("nan")]}
        new = {"p": {"w": np.asarray(state["p"]["w"]) + 1.0}}
        return new, {"loss": 1.0, "per_rank_losses": [1.0, 1.0]}

    state0 = {"p": {"w": np.zeros(2)}}
    state, step, relaunches = run_with_failure_handling(
        train_one_step, state=state0, checkpointer=ck, cluster=cluster,
        num_steps=20)
    assert step == 20
    assert relaunches == 2
    assert len(cluster.replaced) == 2
    # soft failure consumed a NaN step but training still completed
    assert calls["hard_done"] and calls["soft_done"]


def test_failure_before_first_checkpoint_resets_to_initial(tmp_path):
    """A failure with no valid checkpoint yet must restart from the *initial*
    state, not keep partial updates (which would double-apply early steps)."""
    ck = Checkpointer(str(tmp_path), interval=5)
    cluster = ClusterManager(n_active=2, n_buffer=1)
    calls = {"done": False}

    def train_one_step(state, step):
        if step == 2 and not calls["done"]:
            calls["done"] = True
            raise NodeFailure(0, "hard")
        return {"w": state["w"] + 1.0}, {"loss": 1.0}

    state, step, relaunches = run_with_failure_handling(
        train_one_step, state={"w": np.zeros(1)}, checkpointer=ck,
        cluster=cluster, num_steps=4)
    assert step == 4 and relaunches == 1
    assert state["w"][0] == 4.0      # not 6.0: steps 0-1 replayed, not stacked


def test_launcher_fault_injection_matches_uninterrupted(tmp_path):
    """ISSUE 2 satellite: the real launcher path (repro.launch.train.run ->
    run_with_failure_handling) recovers a hard failure at step 7 and a soft
    NaN at step 12 via buffer-node swaps + restore-from-newest-valid, and the
    replayed run is bit-identical to an uninterrupted one."""
    import json

    from repro.launch.train import run

    kw = dict(steps=18, batch=4, seq=32, d_model=64, ckpt_interval=5,
              log_every=100)
    clean = run("mula-1b", out=str(tmp_path / "clean"), **kw)
    faulty = run("mula-1b", out=str(tmp_path / "faulty"),
                 inject_hard_at=7, inject_soft_at=12, **kw)

    # one buffer-node swap per failure
    assert faulty.relaunches == 2
    assert len(faulty.replaced) == 2
    assert clean.relaunches == 0

    # restore-from-newest-valid: the dual slots hold the two newest ckpts
    # (steps 10 and 15), not anything stale from before the failures
    root = tmp_path / "faulty" / "ckpt"
    slot_steps = set()
    for slot in ("ckpt-1", "ckpt-2"):
        with open(root / slot / "MANIFEST.json") as f:
            m = json.load(f)
        assert m["valid"]
        slot_steps.add(m["step"])
    assert slot_steps == {10, 15}

    # replayed history (and so the final loss) is bit-identical
    assert [h["loss"] for h in clean] == [h["loss"] for h in faulty]
    assert [h["step"] for h in faulty] == list(range(18))

    # summary.json records the fault-tolerance outcome
    with open(tmp_path / "faulty" / "summary.json") as f:
        summary = json.load(f)
    assert summary["relaunches"] == 2 and summary["steps"] == 18
