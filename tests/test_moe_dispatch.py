"""Dropless dispatch + per-expert telemetry: the routing-parity suite.

The dispatch mode (``MoEConfig.dispatch``) selects how the slot pool is
sized: 'capacity' (paper default — capacity_factor bounds the pool, tokens
over capacity are dropped) or 'dropless' (the pool covers the worst-case
routing, every (token, expert) pair is computed). Dropless is exactly the
naive math for ANY routing, independent of pool-geometry knobs like
``c_align`` — which is what makes pp=1 and pp>1 losses comparable at
shapes where the capacity path's different pool geometries diverge
(the c_align parity test at the bottom pins that).

Property tests run on the hypothesis stub when hypothesis isn't installed
(tests/_hypothesis_stub.py — deterministic sampling, same @given API).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ParallelConfig, TrainConfig, get_config, reduced
from repro.configs.base import ModelConfig, MoEConfig
from repro.core import moe as M
from repro.core.router import route
from repro.train import init_state, make_train_step


def make_cfg(E=8, K=2, d=32, f=16, cf=None, **kw):
    return ModelConfig(
        name="t", arch_type="moe", num_layers=1, d_model=d, num_heads=2,
        num_kv_heads=2, d_ff=0, vocab_size=64,
        moe=MoEConfig(num_experts=E, experts_per_token=K, d_ff_expert=f,
                      capacity_factor=cf if cf is not None else E / K, **kw))


# ---------------------------------------------------------------------------
# make_dispatch_plan properties (Stages 2+3)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(2, 16), st.integers(1, 4), st.integers(8, 96),
       st.integers(0, 3))
def test_dispatch_plan_conservation(E, K, T, seed):
    """routed + dropped == T*K, for any pool size — nothing is silently
    lost even when the pool is far too small."""
    K = min(K, E)
    idx = jax.random.randint(jax.random.PRNGKey(seed), (T, K), 0, E)
    for rows in (8, M.pool_size(T, K, E, E, 1.0),
                 M.dropless_pool_rows(T, K, E)):
        plan = M.make_dispatch_plan(idx, num_experts=E, pool_rows=rows)
        assert int(plan.valid.sum()) + int(plan.drops) == T * K
        assert int(plan.counts.sum()) == T * K   # counts are pre-drop


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 16), st.integers(1, 4), st.integers(8, 96),
       st.integers(0, 3))
def test_dispatch_plan_group_sizes_cover_pool(E, K, T, seed):
    """Ragged groups tile the pool: offsets are monotone, fit in pool_rows,
    and every valid slot lands inside the occupied prefix."""
    K = min(K, E)
    idx = jax.random.randint(jax.random.PRNGKey(seed), (T, K), 0, E)
    rows = M.dropless_pool_rows(T, K, E)
    plan = M.make_dispatch_plan(idx, num_experts=E, pool_rows=rows)
    gs = np.array(plan.group_sizes)
    assert (gs >= 0).all()
    occupied = int(gs.sum())
    assert occupied <= rows
    slot = np.array(plan.slot)
    valid = np.array(plan.valid)
    if valid.any():
        assert slot[valid].max() < occupied


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(1, 2), st.integers(8, 64),
       st.integers(0, 3))
def test_dispatch_plan_uniform_capacity_shape(E, K, T, seed):
    """uniform_capacity: every group is exactly pool_rows // EL (the
    (EL, C, d) reshape contract of the XLA backend)."""
    K = min(K, E)
    idx = jax.random.randint(jax.random.PRNGKey(seed), (T, K), 0, E)
    rows = M.pool_size(T, K, E, E, float(E))
    rows = (rows // E) * E          # divisible, as dispatch_compute_combine
    plan = M.make_dispatch_plan(idx, num_experts=E, pool_rows=rows,
                                uniform_capacity=True)
    gs = np.array(plan.group_sizes)
    assert (gs == rows // E).all()
    assert int(gs.sum()) == rows


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.integers(1, 2), st.integers(0, 4))
def test_dropless_pool_survives_adversarial_routing(E, K, seed):
    """The dropless bound holds at its worst case: ALL (t, k) pairs routed
    to a single expert still produce zero drops."""
    T = 48
    e = seed % E
    idx = jnp.full((T, K), e, jnp.int32)
    rows = M.dropless_pool_rows(T, K, E)
    plan = M.make_dispatch_plan(idx, num_experts=E, pool_rows=rows)
    assert int(plan.drops) == 0
    assert int(plan.counts[e]) == T * K


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 4))
def test_dropless_combine_permutation_invariance(seed):
    """Permuting the token order permutes the output rows and nothing else:
    the sort-based dispatch has no order-dependent drop behavior under
    dropless."""
    cfg = make_cfg(E=4, K=2, cf=0.1)      # cf ignored by dropless
    p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 10), (32, 32))
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 20), 32)
    out, _, stats = M.moe_dropless(p, x, cfg.moe)
    out_p, _, stats_p = M.moe_dropless(p, x[perm], cfg.moe)
    np.testing.assert_allclose(np.asarray(out)[np.asarray(perm)],
                               np.asarray(out_p), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(stats.counts),
                                  np.asarray(stats_p.counts))


# ---------------------------------------------------------------------------
# golden parity: dropless == naive, capacity == dropless when nothing drops
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E", [4, 8])
@pytest.mark.parametrize("K", [1, 2])
def test_dropless_matches_naive_golden(E, K):
    """moe_dropless == moe_naive (forward + every gradient) at a tight
    capacity_factor where the capacity path would drop — the tentpole's
    correctness contract."""
    cfg = make_cfg(E=E, K=K, cf=0.25)
    p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    ref, _ = M.moe_naive(p, x, cfg.moe)
    out, _, stats = M.moe_dropless(p, x, cfg.moe)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    assert float(stats.drops) == 0.0
    assert int(stats.counts.sum()) == 64 * K
    g1 = jax.grad(lambda p: (M.moe_dropless(p, x, cfg.moe)[0] ** 2).sum())(p)
    g2 = jax.grad(lambda p: (M.moe_naive(p, x, cfg.moe)[0] ** 2).sum())(p)
    for k in ("router", "gate", "up", "down"):
        np.testing.assert_allclose(g1[k], g2[k], atol=1e-4, err_msg=k)


def test_capacity_equals_dropless_at_full_capacity():
    """At capacity_factor = E/K the capacity pool also fits every pair, so
    both dispatch modes compute the identical function."""
    cfg = make_cfg(E=8, K=2, cf=4.0)
    p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    out_c, _ = M.moe_dense_capacity(p, x, cfg.moe)
    out_d, _, stats = M.moe_dropless(p, x, cfg.moe)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_d),
                               atol=1e-5)
    assert float(stats.drops) == 0.0


def test_sparse_moe_block_dispatch_modes():
    """cfg.moe.dispatch drives the block: dropless reports zero drops at a
    capacity_factor where the capacity path demonstrably drops."""
    base = make_cfg(E=8, K=2, cf=0.25)
    p = M.init_moe_block(jax.random.PRNGKey(0), base)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32)).reshape(4, 16, 32)
    _, _, _, st_cap = M.sparse_moe_block(p, x, base)
    assert float(st_cap.drops) > 0
    drop = dataclasses.replace(base, moe=dataclasses.replace(
        base.moe, dispatch="dropless"))
    out, aux, z, st_dl = M.sparse_moe_block(p, x, drop)
    assert float(st_dl.drops) == 0.0
    assert int(st_dl.counts.sum()) == 4 * 16 * 2
    # dropless through the block == naive reference
    ref, _ = M.moe_naive(p, x.reshape(64, 32), base.moe)
    np.testing.assert_allclose(np.asarray(out).reshape(64, 32),
                               np.asarray(ref), atol=1e-5)


def test_moe_config_validates_dispatch():
    with pytest.raises(ValueError, match="dispatch"):
        make_cfg(dispatch="sometimes")
    with pytest.raises(ValueError, match="moe_dispatch"):
        ParallelConfig(moe_dispatch="sometimes")


def test_fsmoe_a2a_rejects_dropless():
    """stage1='a2a' send buffers are capacity-bounded by construction —
    dropless must fail loudly, never silently drop."""
    cfg = make_cfg(E=4, K=2, moe_impl="fsmoe", stage1="a2a",
                   dispatch="dropless")
    p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="a2a"):
        M.sparse_moe_block(p, x, cfg, mesh=mesh)


# ---------------------------------------------------------------------------
# train-step telemetry (moe_stats -> metrics)
# ---------------------------------------------------------------------------

def _tc(seq=32, batch=4):
    return TrainConfig(param_dtype="float32", compute_dtype="float32",
                       grad_reduce_dtype="float32", lr_peak=1e-3,
                       lr_min=1e-4, warmup_steps=2, total_steps=10,
                       seq_len=seq, global_batch=batch)


def _moe_train_cfg(cf=None):
    cfg = reduced(get_config("mula-7b-a1b"), layers=2, d_model=64)
    if cf is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=cf))
    return cfg


def _run_step(cfg, par, batch=4, seq=32, seed=1):
    tc = _tc(seq, batch)
    state = init_state(jax.random.PRNGKey(0), cfg, tc)
    toks = jax.random.randint(jax.random.PRNGKey(seed), (batch, seq + 1), 0,
                              cfg.vocab_size)
    b = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    return jax.jit(make_train_step(cfg, par, tc))(state, b)


@pytest.mark.parametrize("nmb", [1, 2])
def test_train_step_moe_stats_counts_conserve(nmb):
    """metrics['moe_counts'] sums to tokens*top_k for the whole batch, for
    both the single-shot and microbatch-accumulation paths."""
    cfg = _moe_train_cfg()
    B, S, K = 4, 32, cfg.moe.experts_per_token
    _, m = _run_step(cfg, ParallelConfig(microbatches=nmb,
                                         moe_dispatch="dropless"),
                     batch=B, seq=S)
    assert m["moe_counts"].shape == (cfg.moe.num_experts,)
    np.testing.assert_allclose(float(m["moe_counts"].sum()), B * S * K,
                               atol=1e-3)
    assert float(m["moe_drops"]) == 0.0
    np.testing.assert_allclose(float(m["moe_load"].sum()), 1.0, atol=1e-5)


def test_train_step_capacity_reports_drops():
    """A starved capacity pool surfaces real drop counts; the same model
    under dispatch='dropless' reports zero."""
    cfg = _moe_train_cfg(cf=0.1)
    _, m_cap = _run_step(cfg, ParallelConfig(moe_dispatch="capacity"))
    assert float(m_cap["moe_drops"]) > 0
    _, m_dl = _run_step(cfg, ParallelConfig(moe_dispatch="dropless"))
    assert float(m_dl["moe_drops"]) == 0.0


def test_parallel_config_dispatch_overrides_model():
    """ParallelConfig.moe_dispatch is authoritative over MoEConfig.dispatch
    inside make_train_step — the plan pins one path for the whole run."""
    cfg = _moe_train_cfg(cf=0.1)      # model says capacity + starved pool
    assert cfg.moe.dispatch == "capacity"
    _, m = _run_step(cfg, ParallelConfig(moe_dispatch="dropless"))
    assert float(m["moe_drops"]) == 0.0    # dropless won


def test_pp_train_step_moe_stats():
    """The pipeline executors thread per-expert counts through the
    (pp,)-leaf scalar channels: pp=2 telemetry == non-pp telemetry."""
    cfg = _moe_train_cfg()
    B, S, K = 8, 16, cfg.moe.experts_per_token
    _, m_ref = _run_step(cfg, ParallelConfig(microbatches=4,
                                             moe_dispatch="dropless"),
                         batch=B, seq=S)
    _, m_pp = _run_step(cfg, ParallelConfig(microbatches=4, pp_stages=2,
                                            moe_dispatch="dropless"),
                        batch=B, seq=S)
    np.testing.assert_allclose(np.asarray(m_ref["moe_counts"]),
                               np.asarray(m_pp["moe_counts"]), atol=1e-3)
    np.testing.assert_allclose(float(m_pp["moe_counts"].sum()), B * S * K,
                               atol=1e-3)
    assert float(m_pp["moe_drops"]) == 0.0


# ---------------------------------------------------------------------------
# mesh8: dropless under EP x TP, and the c_align parity gap
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.slow
def test_dropless_ep_tp_matches_naive_mesh8(mesh8):
    """Dropless through the EP shard_map path on a (data=2, ep=2, tp=2)
    mesh: forward == naive, stats.drops == 0, counts conserve — at a
    capacity_factor that would starve the capacity path."""
    out = mesh8("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType
        from repro.configs.base import ModelConfig, MoEConfig
        from repro.core import moe as M
        mesh = jax.make_mesh((2, 2, 2), ("data", "ep", "tp"),
                             axis_types=(AxisType.Auto,)*3)
        cfg = ModelConfig(name="t", arch_type="moe", num_layers=1, d_model=32,
                          num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64,
                          moe=MoEConfig(num_experts=4, experts_per_token=2,
                                        d_ff_expert=16, capacity_factor=0.25,
                                        moe_impl="fsmoe",
                                        dispatch="dropless"))
        p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        ref, _ = M.moe_naive(p, x, cfg.moe)
        pspec = {"router": P(), "gate": P("ep", None, "tp"),
                 "up": P("ep", None, "tp"), "down": P("ep", "tp", None)}
        ps = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                          p, pspec)
        xs = jax.device_put(x, NamedSharding(mesh, P(("data", "ep"), None)))
        def f(p, x):
            out, aux, z, stats = M.sparse_moe_block(
                p, x.reshape(4, 16, 32), cfg, mesh=mesh, ep_axis="ep",
                tp_axis="tp", batch_axes=("data",))
            return out.reshape(64, 32), stats
        out, stats = jax.jit(f)(ps, xs)
        assert np.allclose(ref, out, atol=1e-4), "forward mismatch"
        assert float(stats.drops) == 0.0, stats.drops
        assert int(stats.counts.sum()) == 64 * 2, stats.counts
        g1 = jax.jit(jax.grad(lambda p, x: (f(p, x)[0]**2).sum()))(ps, xs)
        g2 = jax.grad(lambda p: (M.moe_naive(p, x, cfg.moe)[0]**2).sum())(p)
        for k in ("router", "gate", "up", "down"):
            assert np.allclose(g1[k], g2[k], atol=1e-3), k
        print("DROPLESS-EP-TP-OK")
    """, timeout=1200)
    assert "DROPLESS-EP-TP-OK" in out


@pytest.mark.distributed
@pytest.mark.slow
def test_c_align_parity_gap_closed_by_dropless_mesh8(mesh8):
    """THE parity test this PR exists for. A non-PP on-mesh step pads the
    capacity pool to c_align = batch-shard count; the PP executors run the
    blocks with c_align = 1. At a starved capacity_factor the two pool
    geometries drop different tokens and the losses diverge — that shape
    was previously unblessed. Under dispatch='dropless' the pool geometry
    is irrelevant and the losses agree."""
    out = mesh8("""
        import dataclasses
        import jax, numpy as np
        from repro.configs import get_config, reduced, TrainConfig, ParallelConfig
        from repro.train import init_state, make_train_step, train_state_shardings
        from repro.parallel.plan import ParallelPlan
        from repro.parallel.sharding import batch_sharding

        cfg0 = reduced(get_config("mula-7b-a1b"), layers=2, d_model=64)
        cfg0 = dataclasses.replace(cfg0, moe=dataclasses.replace(
            cfg0.moe, capacity_factor=0.25))    # starved: capacity drops
        tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                         grad_reduce_dtype="float32", lr_peak=1e-3,
                         lr_min=1e-4, warmup_steps=2, total_steps=10,
                         seq_len=32, global_batch=8)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0,
                                  cfg0.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

        def run(mesh_spec, pp, dispatch):
            plan = ParallelPlan.from_legacy(mesh_spec, cfg=cfg0) \
                .resolve(cfg0, global_batch=8)
            rules = plan.rules
            state = init_state(jax.random.PRNGKey(0), cfg0, tc, plan=plan)
            ssh = train_state_shardings(state.params, rules, "none")
            par = ParallelConfig(microbatches=4, pp_stages=pp,
                                 pp_schedule="1f1b",
                                 pp_impl="masked" if pp > 1 else "shardmap",
                                 moe_dispatch=dispatch)
            step = make_train_step(cfg0, par, tc, plan=plan,
                                   state_shardings=ssh)
            bdev = jax.tree.map(
                lambda a: jax.device_put(a, batch_sharding(rules)), batch)
            _, m = step(state, bdev)
            return float(m["loss"]), float(m["moe_drops"])

        # non-PP on an 8-way data mesh (c_align=8) vs PP=2 (c_align=1)
        l_cap_nopp, d_cap_nopp = run("8", 1, "capacity")
        l_cap_pp, d_cap_pp = run("2,2,2", 2, "capacity")
        l_dl_nopp, d_dl_nopp = run("8", 1, "dropless")
        l_dl_pp, d_dl_pp = run("2,2,2", 2, "dropless")
        print("capacity:", l_cap_nopp, l_cap_pp,
              "drops:", d_cap_nopp, d_cap_pp)
        print("dropless:", l_dl_nopp, l_dl_pp)
        # the starved capacity path drops on at least one geometry and the
        # two geometries disagree on the loss
        assert max(d_cap_nopp, d_cap_pp) > 0
        assert abs(l_cap_nopp - l_cap_pp) > 1e-6, "gap vanished: retune cf"
        # dropless: geometry-independent -> pp=1 and pp=2 agree
        assert d_dl_nopp == 0.0 and d_dl_pp == 0.0
        assert abs(l_dl_nopp - l_dl_pp) <= 1e-6, (l_dl_nopp, l_dl_pp)
        print("CALIGN-PARITY-OK")
    """, timeout=1800)
    assert "CALIGN-PARITY-OK" in out
