"""Shardlint layer 2 (repro.analysis.lint): the current tree passes
clean, and each AST rule fires on a synthetic violation — including the
acceptance criterion that a file using raw ``shard_map`` exits non-zero.
The lint must stay importable without jax (CI runs it pre-install)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import lint as L

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rules(violations):
    return sorted({v[0] for v in violations})


# --- the current tree is clean --------------------------------------------

def test_repo_tree_passes_clean():
    paths = [os.path.join(ROOT, d) for d in ("src", "tests", "benchmarks")]
    vs = L.lint_paths(paths)
    assert vs == [], "\n".join(f"{p}:{ln}: {r} {m}" for r, p, ln, m in vs)


def test_cli_exits_zero_on_tree():
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "src", "tests",
         "benchmarks"],
        cwd=ROOT, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "clean" in r.stdout


def test_lint_importable_without_jax():
    """The CI lint job runs before any jax install — importing the lint
    module must not pull jax in."""
    r = subprocess.run(
        [sys.executable, "-c",
         "import sys; sys.modules['jax'] = None\n"
         "import repro.analysis.lint as L\n"
         "print(len(L.ALLOWLIST))"],
        cwd=ROOT, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert r.returncode == 0, r.stdout + r.stderr


# --- SL001: raw shard_map -------------------------------------------------

@pytest.mark.parametrize("src", [
    "from jax.experimental.shard_map import shard_map",
    "import jax.experimental.shard_map as sm",
    "from jax.experimental import shard_map",
    "def f():\n    return jax.experimental.shard_map.shard_map",
])
def test_sl001_raw_shard_map(src):
    assert _rules(L.lint_source(src, "synthetic/mod.py")) == ["SL001"]


def test_sl001_allowlisted_in_compat():
    src = "from jax.experimental.shard_map import shard_map"
    assert L.lint_source(src, "src/repro/compat.py") == []


def test_sl001_cli_exits_nonzero(tmp_path):
    """Acceptance criterion: a synthetic file using raw shard_map makes
    `python -m repro.analysis.lint` exit non-zero."""
    bad = tmp_path / "uses_raw_shard_map.py"
    bad.write_text("from jax.experimental.shard_map import shard_map\n")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(bad)],
        cwd=ROOT, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert r.returncode == 1, r.stdout + r.stderr
    assert "SL001" in r.stdout


# --- SL002: ragged_dot outside the allowlist ------------------------------

def test_sl002_ragged_dot():
    src = "import jax\ny = jax.lax.ragged_dot(a, b, gs)"
    assert _rules(L.lint_source(src, "src/repro/core/new_moe.py")) \
        == ["SL002"]


def test_sl002_allowlisted_in_ref():
    src = "y = jax.lax.ragged_dot(a, b, gs)"
    assert L.lint_source(src, "src/repro/kernels/ref.py") == []


# --- SL003: host transfers in traced step-building modules ----------------

def test_sl003_device_get_and_np_asarray():
    src = textwrap.dedent("""
        import jax
        import numpy as np
        def step(x):
            host = jax.device_get(x)
            arr = np.asarray(x)
            return host, arr
    """)
    vs = L.lint_source(src, "src/repro/train/new_step.py")
    assert _rules(vs) == ["SL003"] and len(vs) == 2


def test_sl003_scoped_to_traced_modules():
    # the same constructs are fine in benches/launch tooling
    src = "import jax\nimport numpy as np\n" \
          "x = np.asarray(jax.device_get(y))"
    assert L.lint_source(src, "benchmarks/bench_new.py") == []


def test_sl003_jnp_asarray_ok():
    src = "import jax.numpy as jnp\nx = jnp.asarray(y)"
    assert L.lint_source(src, "src/repro/train/new_step.py") == []


def test_sl003_traced_override():
    src = "import numpy as np\nx = np.asarray(y)"
    assert L.lint_source(src, "/tmp/elsewhere/f.py") == []
    vs = L.lint_source(src, "/tmp/elsewhere/f.py",
                       traced_dirs=("/tmp/elsewhere/",))
    assert _rules(vs) == ["SL003"]


# --- SL004: retired kernel-knob aliases are tombstoned --------------------

@pytest.mark.parametrize("src", [
    # writes
    "from repro.kernels import ops\nops.KERNEL_CONFIG['tile_m'] = 8",
    "import repro.models.layers as L\nL.ATTN_IMPL = 'pallas'",
    "KERNEL_CONFIG = make_config()",
    # reads are violations too: the symbols no longer exist
    "impl = layers.ATTN_IMPL",
    "tm = ops.KERNEL_CONFIG['tile_m']",
    # and so are imports of the retired names
    "from repro.kernels.ops import KERNEL_CONFIG",
    "from repro.models.layers import ATTN_IMPL as AI",
])
def test_sl004_any_alias_occurrence(src):
    assert _rules(L.lint_source(src, "src/repro/new_tool.py")) == ["SL004"]


def test_sl004_has_no_allowlist():
    """The tombstone is absolute: no path is allowlisted, and string or
    docstring mentions (docs, this test file) stay lint-clean."""
    assert L.ALLOWLIST["SL004"] == ()
    src = 'msg = "KERNEL_CONFIG and ATTN_IMPL are retired"\n' \
          'def f():\n    "replaces ATTN_IMPL"\n'
    assert L.lint_source(src, "src/repro/new_tool.py") == []


# --- robustness -----------------------------------------------------------

def test_syntax_error_is_reported_not_raised():
    vs = L.lint_source("def broken(:\n", "synthetic/x.py")
    assert _rules(vs) == ["SL000"]


def test_allow_extra_suppresses():
    src = "y = jax.lax.ragged_dot(a, b, gs)"
    assert L.lint_source(src, "scratch/probe.py",
                         allow_extra=("scratch/probe.py",)) == []
