"""Checkpointing (paper §4): dual rotation, crash recovery, model-only,
DP-scattered writer assignment, bit-exact roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (Checkpointer, dp_scattered_writers,
                              save_pytree, load_pytree)


def state_like(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.arange(3.0)},
            "step": jnp.array(int(v))}


def test_roundtrip_bit_exact(tmp_path):
    s = state_like(3.5)
    save_pytree(s, str(tmp_path / "x.npz"))
    s2 = load_pytree(s, str(tmp_path / "x.npz"))
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(s2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_dual_rotation(tmp_path):
    ck = Checkpointer(str(tmp_path), interval=1)
    ck.save(state_like(1), 1000)
    ck.save(state_like(2), 2000)
    ck.save(state_like(3), 3000)     # overwrites the oldest (step 1000)
    steps = sorted(ck._slot_step(s) for s in ck.slots)
    assert steps == [2000, 3000]
    restored, step = ck.restore(state_like())
    assert step == 3000
    assert float(np.asarray(restored["params"]["w"]).max()) == 3.0


def test_crash_during_checkpoint_keeps_valid_one(tmp_path):
    """Paper scenario: failure while writing ckpt-1 must leave ckpt-2
    restorable."""
    ck = Checkpointer(str(tmp_path), interval=1)
    ck.save(state_like(1), 1000)
    ck.save(state_like(2), 2000)
    ck.save(state_like(9), 3000, fail_after_write=True)   # no MANIFEST
    restored, step = ck.restore(state_like())
    assert step == 2000                                   # fell back
    assert float(np.asarray(restored["params"]["w"]).max()) == 2.0


def test_model_only_persistent(tmp_path):
    """Model-only checkpoints accumulate (never rotated) and restore params
    without optimizer state."""
    ck = Checkpointer(str(tmp_path), interval=10, model_only_interval=10)
    params = state_like(5)["params"]
    for step in (10, 20, 30):
        ck.save_model_only(params, step)
    assert len(ck.list_model_only()) == 3
    p = ck.restore_model_only(params, 20)
    assert np.array_equal(np.asarray(p["w"]), np.asarray(params["w"]))


def test_model_only_is_smaller_than_full(tmp_path):
    """Paper: model-only checkpoint is ~8x smaller for bf16+AdamW."""
    params = {"w": jnp.zeros((64, 64), jnp.bfloat16)}
    full = {"params": params,
            "master": jax.tree.map(lambda x: x.astype(jnp.float32), params),
            "m": jax.tree.map(lambda x: x.astype(jnp.float32), params),
            "v": jax.tree.map(lambda x: x.astype(jnp.float32), params)}
    save_pytree(params, str(tmp_path / "model.npz"))
    save_pytree(full, str(tmp_path / "full.npz"))
    ratio = os.path.getsize(tmp_path / "full.npz") / \
        os.path.getsize(tmp_path / "model.npz")
    assert ratio > 5


def test_maybe_save_intervals(tmp_path):
    ck = Checkpointer(str(tmp_path), interval=10, model_only_interval=5)
    wrote = []
    for step in range(1, 21):
        wrote += ck.maybe_save(state_like(step), state_like(step)["params"],
                               step)
    assert len(ck.list_model_only()) == 4      # 5,10,15,20
    _, step = ck.restore(state_like())
    assert step == 20


def test_dp_scattered_writers():
    """Paper: shard m written by dp rank m % DP — spread, not concentrated."""
    w = dp_scattered_writers(num_model_shards=12, dp_size=12)
    assert list(w.values()) == list(range(12))     # 12 distinct nodes
    w2 = dp_scattered_writers(num_model_shards=12, dp_size=4)
    loads = np.bincount(list(w2.values()))
    assert loads.max() - loads.min() == 0          # perfectly balanced
