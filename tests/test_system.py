"""End-to-end behaviour tests: full launcher runs (data pipeline -> train ->
checkpoint -> resume), dry-run roofline plumbing, serve loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_train_launcher_end_to_end(tmp_path):
    from repro.launch.train import run
    hist = run("mula-7b-a1b", steps=12, batch=4, seq=64,
               out=str(tmp_path / "run"), ckpt_interval=5, d_model=64)
    assert len(hist) == 12
    losses = [h["loss"] for h in hist]
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    # dual checkpoints + model-only exist
    ckdir = tmp_path / "run" / "ckpt"
    assert (ckdir / "ckpt-1").exists() or (ckdir / "ckpt-2").exists()


def test_train_launcher_resume(tmp_path):
    from repro.launch.train import run
    out = str(tmp_path / "run")
    run("mula-1b", steps=10, batch=4, seq=64, out=out, ckpt_interval=5,
        d_model=64)
    hist2 = run("mula-1b", steps=14, batch=4, seq=64, out=out,
                ckpt_interval=5, d_model=64)
    # first run checkpointed after step 5 (10 steps, interval 5) => resume
    # continues at 6 and trains to 13
    steps = [h["step"] for h in hist2]
    assert steps[0] == 6 and steps[-1] == 13


def test_serve_loop_generates():
    """Batched greedy decode over a prompt — the serving path end-to-end."""
    from repro.configs import get_config, reduced
    from repro.models import init_params, init_cache, decode_step
    cfg = reduced(get_config("falcon-mamba-7b"), d_model=64)
    p = init_params(jax.random.PRNGKey(0), cfg)
    B, steps = 4, 12
    cache = init_cache(cfg, B, steps, jnp.float32)
    tok = jnp.ones((B, 1), jnp.int32)
    step = jax.jit(lambda p, t, c, i: decode_step(p, t, c, i, cfg,
                                                  compute_dtype=jnp.float32))
    outs = []
    for i in range(steps):
        logits, cache = step(p, tok, cache, i)
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1).astype(
            jnp.int32)
        outs.append(tok)
    gen = jnp.concatenate(outs, axis=1)
    assert gen.shape == (B, steps)
    assert bool((gen >= 0).all()) and bool((gen < cfg.vocab_size).all())


def test_roofline_collective_parser():
    from repro.launch.roofline import collective_bytes
    hlo = """
      %ag = bf16[16,128]{1,0} all-gather(bf16[2,128] %x), replica_groups=[8,8]<=[64], dimensions={0}
      %ar = f32[256]{0} all-reduce(f32[256] %y), replica_groups={{0,1,2,3}}, to_apply=%add
      %rs = f32[32]{0} reduce-scatter(f32[256] %z), replica_groups=[4,8]<=[32], dimensions={0}
      %cp = bf16[64]{0} collective-permute(bf16[64] %w), source_target_pairs={{0,1}}
    """
    c = collective_bytes(hlo)
    assert c["all-gather"] == pytest.approx(16 * 128 * 2 * 7 / 8)
    assert c["all-reduce"] == pytest.approx(2 * 256 * 4 * 3 / 4)
    assert c["reduce-scatter"] == pytest.approx(32 * 4 * 7)
    assert c["collective-permute"] == pytest.approx(64 * 2)
    assert c["unknown_dtypes"] == []
    assert c["total"] == sum(v for k, v in c.items()
                             if k not in ("total", "unknown_dtypes"))


def test_nan_failure_aborts_training():
    """Soft-failure wiring in the launcher: NaN loss raises NodeFailure."""
    from repro.ft import NaNMonitor, NodeFailure
    mon = NaNMonitor()
    with pytest.raises(NodeFailure):
        mon.check([float("nan")])
