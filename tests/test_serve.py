"""Continuous-batching serve engine (repro/serve/) — the contracts that make
continuous batching safe to ship:

  * batching transparency: a request's tokens don't depend on batch
    composition, slot placement, or churn around it;
  * slot reuse hygiene: evict + readmit on the same slot leaks nothing;
  * sampling determinism: (seed, position)-keyed sampling with per-request
    temperature/top-k/top-p.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import init_params, init_cache, decode_step
from repro.serve import (FIFOScheduler, Request, SamplingParams, ServeEngine,
                         SlotKVPool, sample_tokens)
from repro.serve.sampling import position_keys


@pytest.fixture(scope="module")
def moe_setup():
    cfg = reduced(get_config("mixtral-8x7b"), d_model=64, vocab=128)
    cfg = dataclasses.replace(cfg, sliding_window=0)     # full attention
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def swa_setup():
    cfg = reduced(get_config("mixtral-8x7b"), d_model=64, vocab=128)
    cfg = dataclasses.replace(cfg, sliding_window=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(n, seed=0, lo=3, hi=20):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, 127, size=rng.randint(lo, hi)).tolist()
            for _ in range(n)]


def _single(params, cfg, prompt, max_new, sp):
    """Reference: the same request alone in a one-slot engine."""
    eng = ServeEngine(params, cfg, num_slots=1, max_len=64)
    eng.submit(prompt, max_new, sp)
    return eng.run()[0].tokens


# ---------------------------------------------------------------------------
# batching transparency
# ---------------------------------------------------------------------------

def test_continuous_matches_single_request(moe_setup):
    """7 churning requests over 3 slots reproduce each request's solo run
    token-for-token (greedy and sampled rows mixed)."""
    cfg, params = moe_setup
    prompts = _prompts(7)
    max_new = [5, 9, 3, 12, 7, 4, 8]
    sps = [SamplingParams(temperature=0.8 if i % 2 else 0.0, top_k=20,
                          top_p=0.9, seed=100 + i) for i in range(7)]
    eng = ServeEngine(params, cfg, num_slots=3, max_len=64)
    for p, mn, sp in zip(prompts, max_new, sps):
        eng.submit(p, mn, sp)
    res = eng.run()
    assert len(res) == 7
    for i in range(7):
        assert res[i].tokens == _single(params, cfg, prompts[i], max_new[i],
                                        sps[i]), f"req {i} diverged"


def test_continuous_matches_single_request_sliding_window(swa_setup):
    """Same transparency with ring-buffer (sliding-window) caches — per-slot
    ring validity masks must not see neighbours."""
    cfg, params = swa_setup
    prompts = _prompts(4, seed=1)
    for i, p in enumerate(prompts):
        solo = _single(params, cfg, p, 10, SamplingParams())
        assert len(solo) == 10
    eng = ServeEngine(params, cfg, num_slots=2, max_len=64)
    for p in prompts:
        eng.submit(p, 10, SamplingParams())
    res = eng.run()
    for i, p in enumerate(prompts):
        assert res[i].tokens == _single(params, cfg, p, 10, SamplingParams())


def test_decode_matches_lockstep_decode_step(moe_setup):
    """The engine's vector-position decode is the same lowering as the
    classic scalar-index decode_step when positions happen to agree."""
    cfg, params = moe_setup
    B, T = 3, 6
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, 1), 1, 127)
    c1 = init_cache(cfg, B, 32, jnp.float32)
    c2 = init_cache(cfg, B, 32, jnp.float32)
    t1 = t2 = toks
    for i in range(T):
        l1, c1 = decode_step(params, t1, c1, i, cfg,
                             compute_dtype=jnp.float32)
        l2, c2 = decode_step(params, t2, c2, jnp.full((B,), i, jnp.int32),
                             cfg, compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5)
        t1 = jnp.argmax(l1[:, :, :cfg.vocab_size], -1).astype(jnp.int32)
        t2 = jnp.argmax(l2[:, :, :cfg.vocab_size], -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# slot eviction / reuse
# ---------------------------------------------------------------------------

def test_slot_reuse_no_leakage(moe_setup):
    """A slot that served a long request is reused by a later one without
    cache residue: the readmitted request matches its fresh-pool solo run."""
    cfg, params = moe_setup
    prompts = _prompts(5, seed=2)
    eng = ServeEngine(params, cfg, num_slots=2, max_len=64)
    for p in prompts:
        eng.submit(p, 8, SamplingParams())
    eng.run()
    # 5 requests over 2 slots: slots were recycled at least once
    assert eng.steps > 8
    late = prompts[-1]
    fresh = _single(params, cfg, late, 8, SamplingParams())
    assert eng.results[4].tokens == fresh


def test_pool_alloc_free_cycle(moe_setup):
    cfg, _ = moe_setup
    pool = SlotKVPool(cfg, 3, 16)
    a, b = pool.alloc(), pool.alloc()
    assert {a, b} == {0, 1} and pool.num_free == 1
    pool.free(a)
    assert pool.num_free == 2
    with pytest.raises(ValueError):
        pool.free(a)                       # double free
    pool.alloc(), pool.alloc()
    with pytest.raises(RuntimeError):
        pool.alloc()                       # exhausted
    pool.reset_slot(1)
    assert float(jnp.abs(pool.cache["kv"]["k"][:, 1]).max()) == 0.0


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_sampling_per_request_temperature_and_seed(moe_setup):
    """Greedy rows in a mixed batch take the argmax; sampled rows are
    reproducible from (seed, position) and differ across seeds."""
    cfg, params = moe_setup
    p = _prompts(1, seed=3)[0]
    greedy = _single(params, cfg, p, 12, SamplingParams(temperature=0.0))
    s_a = _single(params, cfg, p, 12, SamplingParams(temperature=1.5, seed=7))
    s_a2 = _single(params, cfg, p, 12, SamplingParams(temperature=1.5, seed=7))
    s_b = _single(params, cfg, p, 12, SamplingParams(temperature=1.5, seed=8))
    assert s_a == s_a2                    # same seed -> same stream
    assert s_a != s_b                     # different seed -> different stream
    assert s_a != greedy                  # hot temperature actually samples
    # and the mixed batch reproduces all three rows
    eng = ServeEngine(params, cfg, num_slots=3, max_len=64)
    eng.submit(p, 12, SamplingParams(temperature=0.0))
    eng.submit(p, 12, SamplingParams(temperature=1.5, seed=7))
    eng.submit(p, 12, SamplingParams(temperature=1.5, seed=8))
    res = eng.run()
    assert [res[i].tokens for i in range(3)] == [greedy, s_a, s_b]


def test_sample_tokens_top_k_top_p_masks():
    """top_k=1 equals greedy regardless of key; top_p≈0 keeps only the mode;
    per-row params apply row-wise."""
    logits = jnp.asarray([[0.0, 3.0, 1.0, 2.0],
                          [5.0, 0.0, 0.0, 0.0]])
    keys = position_keys(jnp.asarray([1, 2]), jnp.asarray([0, 0]))
    out = sample_tokens(logits, keys,
                        temperature=jnp.asarray([1.0, 1.0]),
                        top_k=jnp.asarray([1, 1]),
                        top_p=jnp.asarray([1.0, 1.0]))
    assert out.tolist() == [1, 0]
    out = sample_tokens(logits, keys,
                        temperature=jnp.asarray([1.0, 1.0]),
                        top_k=jnp.asarray([0, 0]),
                        top_p=jnp.asarray([1e-6, 1e-6]))
    assert out.tolist() == [1, 0]
    # greedy row + hot row in one call: greedy row ignores the key
    out = sample_tokens(logits, keys,
                        temperature=jnp.asarray([0.0, 2.0]),
                        top_k=jnp.asarray([0, 0]),
                        top_p=jnp.asarray([1.0, 1.0]))
    assert int(out[0]) == 1


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_fifo_budget_and_arrival_gate():
    sched = FIFOScheduler(prefill_token_budget=10)
    for rid, plen, arr in [(0, 6, 0.0), (1, 6, 0.0), (2, 2, 5.0)]:
        sched.submit(Request(rid, list(range(plen)), arrival_time=arr))
    # budget 10 admits the 6-token head, not the second 6-token request
    first = sched.pop_admissible(free_slots=4, now=1.0)
    assert [r.rid for r in first] == [0]
    # rid=2 hasn't arrived yet at now=1.0
    second = sched.pop_admissible(free_slots=4, now=1.0)
    assert [r.rid for r in second] == [1]
    assert sched.pop_admissible(free_slots=4, now=1.0) == []
    assert [r.rid for r in sched.pop_admissible(4, now=6.0)] == [2]
    # a head-of-line request over the whole budget is admitted alone
    sched.submit(Request(9, list(range(50))))
    assert [r.rid for r in sched.pop_admissible(4)] == [9]


def test_engine_rejects_oversized_and_wrong_arch(moe_setup):
    cfg, params = moe_setup
    eng = ServeEngine(params, cfg, num_slots=1, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(list(range(1, 15)), max_new_tokens=10)
    ssm_cfg = reduced(get_config("falcon-mamba-7b"), d_model=64, vocab=128)
    with pytest.raises(NotImplementedError):
        ServeEngine(params, ssm_cfg)
