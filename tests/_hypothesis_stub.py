"""Deterministic stand-in for ``hypothesis`` when it isn't installed.

Not a property-testing engine — no shrinking, no example database. It
re-implements just the surface the test suite uses (``given``, ``settings``,
``assume``, and a handful of ``strategies``) as a seeded example sampler:
each ``@given`` test runs its boundary cases (all-min, all-max) first, then
random draws from a PRNG seeded by the test's qualname, up to
``max_examples``. conftest.py registers this module under the ``hypothesis``
name only when the real package is missing, so an environment that has
hypothesis gets the real thing.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

DEFAULT_MAX_EXAMPLES = 20


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class _Strategy:
    """A draw function plus optional (min, max) boundary examples."""

    def __init__(self, draw, boundary=()):
        self._draw = draw
        self.boundary = tuple(boundary)

    def example(self, rnd: random.Random):
        return self._draw(rnd)

    def map(self, fn):
        return _Strategy(lambda r: fn(self._draw(r)),
                         boundary=tuple(fn(b) for b in self.boundary))


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value),
                     boundary=(min_value, max_value))


def _floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value),
                     boundary=(min_value, max_value))


def _booleans() -> _Strategy:
    return _Strategy(lambda r: bool(r.getrandbits(1)), boundary=(False, True))


def _sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements),
                     boundary=(elements[0], elements[-1]))


def _lists(elem: _Strategy, *, min_size=0, max_size=10) -> _Strategy:
    def draw(r):
        return [elem.example(r) for _ in range(r.randint(min_size, max_size))]

    return _Strategy(draw)


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.booleans = _booleans
strategies.sampled_from = _sampled_from
strategies.lists = _lists


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Decorator-factory form only (``@settings(...)`` above ``@given``)."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strats: _Strategy):
    """Map strategies onto the test's rightmost parameters (hypothesis
    semantics); earlier parameters stay visible to pytest as fixtures."""

    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        names = [p.name for p in params[len(params) - len(strats):]]

        @functools.wraps(fn)
        def wrapper(**kwargs):
            max_ex = getattr(wrapper, "_stub_max_examples",
                             DEFAULT_MAX_EXAMPLES)
            rnd = random.Random(zlib.crc32(fn.__qualname__.encode()))
            cases = []
            if all(s.boundary for s in strats):
                cases.append(tuple(s.boundary[0] for s in strats))
                cases.append(tuple(s.boundary[-1] for s in strats))
            while len(cases) < max_ex:
                cases.append(tuple(s.example(rnd) for s in strats))
            for case in cases[:max_ex]:
                try:
                    fn(**kwargs, **dict(zip(names, case)))
                except UnsatisfiedAssumption:
                    continue

        wrapper.__signature__ = sig.replace(
            parameters=params[:len(params) - len(strats)])
        return wrapper

    return deco
