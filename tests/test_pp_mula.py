"""Paper-faithful pipeline parallelism on real Mula blocks: the Mula-220B
configuration trains with PP=8 + 1f1b (paper §2.2); this integration test
runs its reduced variant through the actual PP executor with real MoE
transformer stages and checks gradient equivalence with sequential
execution."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.models.model import _moe_block
from repro.parallel import pipeline as PP


@pytest.mark.parametrize("sched", ["gpipe", "1f1b"])
def test_mula_pp_stages_match_sequential(sched):
    cfg = reduced(get_config("mula-220b-a10b"), layers=4, d_model=64)
    params = init_params(jax.random.PRNGKey(0), cfg)
    stages = PP.split_stages(params["layers"], pp=4)   # 1 layer per stage

    def stage_fwd(sp, x):
        def body(h, lp):
            h, _, _, _ = _moe_block(lp, h, cfg, None, "", None)
            return h, None
        x, _ = jax.lax.scan(body, x, sp)
        return x

    def loss_fn(y, mb):
        return (y.astype(jnp.float32) ** 2).mean()

    rng = jax.random.PRNGKey(1)
    mbs = [{"x": jax.random.normal(jax.random.fold_in(rng, i), (2, 8, 64))}
           for i in range(8)]
    loss, grads = PP.pipeline_train_step(stage_fwd, loss_fn, stages, mbs,
                                         sched)

    def ref(stage_params):
        tot = 0.0
        for mb in mbs:
            x = mb["x"]
            for sp in stage_params:
                x = stage_fwd(sp, x)
            tot += loss_fn(x, mb)
        return tot / len(mbs)

    rl, rg = jax.value_and_grad(ref)(stages)
    np.testing.assert_allclose(float(loss), float(rl), rtol=1e-5)
    for g, r in zip(grads, rg):
        for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(r)):
            np.testing.assert_allclose(a, b, atol=1e-4)


def test_mula_220b_paper_pp_config():
    """Paper: Mula-220B trained with PP=8, 1f1b, EP=12 within node. The
    schedule for its setup is valid and has the 1f1b memory profile."""
    n_mb = 16
    t = PP.one_f_one_b_schedule(n_mb, 8)
    PP.validate_schedule(t, n_mb, 8)
    assert PP.peak_inflight(t, 0) == 8
    assert PP.bubble_fraction(n_mb, 8) == pytest.approx(7 / 23)
