"""Pipeline parallelism: schedule validity, 1f1b memory advantage, gradient
equivalence with sequential execution (paper §1 PP, §2.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel import pipeline as PP


@pytest.mark.parametrize("pp,n_mb", [(2, 4), (4, 4), (4, 8), (8, 16)])
@pytest.mark.parametrize("sched", ["gpipe", "1f1b"])
def test_schedules_valid(pp, n_mb, sched):
    t = (PP.gpipe_schedule if sched == "gpipe"
         else PP.one_f_one_b_schedule)(n_mb, pp)
    PP.validate_schedule(t, n_mb, pp)


@pytest.mark.parametrize("pp,n_mb", [(4, 8), (4, 12), (8, 16)])
def test_1f1b_memory_advantage(pp, n_mb):
    """1f1b keeps O(pp) activations in flight; gpipe O(n_mb)."""
    g = PP.gpipe_schedule(n_mb, pp)
    f = PP.one_f_one_b_schedule(n_mb, pp)
    assert PP.peak_inflight(g, 0) == n_mb
    assert PP.peak_inflight(f, 0) == pp


def test_bubble_fraction():
    assert PP.bubble_fraction(8, 4) == pytest.approx(3 / 11)
    # paper's Mula-220B: PP=8; more microbatches -> smaller bubble
    assert PP.bubble_fraction(32, 8) < PP.bubble_fraction(8, 8)


@pytest.mark.parametrize("sched", ["gpipe", "1f1b"])
def test_pipeline_gradients_match_sequential(sched):
    def stage_fwd(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    def loss_fn(y, mb):
        return ((y - mb["y"]) ** 2).mean()

    rng = np.random.default_rng(0)
    pp, n_mb, d = 4, 8, 8
    stage_params = [{"w": jnp.array(rng.normal(size=(d, d)) * 0.3,
                                    jnp.float32),
                     "b": jnp.zeros((d,))} for _ in range(pp)]
    mbs = [{"x": jnp.array(rng.normal(size=(2, d)), jnp.float32),
            "y": jnp.array(rng.normal(size=(2, d)), jnp.float32)}
           for _ in range(n_mb)]
    loss, grads = PP.pipeline_train_step(stage_fwd, loss_fn, stage_params,
                                         mbs, sched)

    def ref(ps):
        tot = 0.0
        for mb in mbs:
            x = mb["x"]
            for p in ps:
                x = stage_fwd(p, x)
            tot += loss_fn(x, mb)
        return tot / n_mb

    rl, rg = jax.value_and_grad(ref)(stage_params)
    np.testing.assert_allclose(float(loss), float(rl), rtol=1e-6)
    for g, r in zip(grads, rg):
        np.testing.assert_allclose(g["w"], r["w"], atol=1e-5)
        np.testing.assert_allclose(g["b"], r["b"], atol=1e-5)


@pytest.mark.parametrize("pp,n_mb,v", [(2, 4, 2), (4, 8, 2), (4, 8, 4)])
def test_interleaved_schedule_valid_and_smaller_bubble(pp, n_mb, v):
    """Paper lists interleaved-1f1b as Optimus' third PP schedule; device
    efficiency must beat plain 1f1b at the same pp/mb."""
    t = PP.interleaved_1f1b_schedule(n_mb, pp, v)
    PP.validate_schedule(t, n_mb, pp, v)
    plain = PP.one_f_one_b_schedule(n_mb, pp)
    eff_i = 2 * n_mb * v / (max(x.clock for x in t) + 1)
    eff_p = 2 * n_mb / (max(x.clock for x in plain) + 1)
    assert eff_i > eff_p


def test_interleaved_gradients_match_sequential():
    def stage_fwd(p, x):
        return jnp.tanh(x @ p["w"])

    def loss_fn(y, mb):
        return ((y - mb["y"]) ** 2).mean()

    rng = np.random.default_rng(0)
    pp, v, n_mb, d = 2, 2, 4, 8
    stages = [{"w": jnp.array(rng.normal(size=(d, d)) * 0.3, jnp.float32)}
              for _ in range(pp * v)]
    mbs = [{"x": jnp.array(rng.normal(size=(2, d)), jnp.float32),
            "y": jnp.array(rng.normal(size=(2, d)), jnp.float32)}
           for _ in range(n_mb)]
    loss, grads = PP.pipeline_train_step(stage_fwd, loss_fn, stages, mbs,
                                         "interleaved-1f1b", v=v)

    def ref(ps):
        tot = 0.0
        for mb in mbs:
            x = mb["x"]
            for p in ps:
                x = stage_fwd(p, x)
            tot += loss_fn(x, mb)
        return tot / n_mb

    rl, rg = jax.value_and_grad(ref)(stages)
    np.testing.assert_allclose(float(loss), float(rl), rtol=1e-6)
    for g, r in zip(grads, rg):
        np.testing.assert_allclose(g["w"], r["w"], atol=1e-5)


def test_split_stages():
    stacked = {"w": jnp.arange(8 * 3).reshape(8, 3)}
    stages = PP.split_stages(stacked, 4)
    assert len(stages) == 4
    assert stages[0]["w"].shape == (2, 3)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(s["w"]) for s in stages]),
        np.asarray(stacked["w"]))


def test_split_stages_indivisible_raises_value_error():
    stacked = {"w": jnp.zeros((6, 3))}
    with pytest.raises(ValueError, match=r"mula-test.*6 layers.*pp_stages=4"):
        PP.split_stages(stacked, 4, name="mula-test")
    with pytest.raises(ValueError, match="pp_stages"):
        PP.stack_stages(stacked, 4)
    with pytest.raises(ValueError):
        PP.split_stages(stacked, 0)


def test_stack_stages_is_contiguous_stage_view():
    stacked = {"w": jnp.arange(8 * 3).reshape(8, 3)}
    view = PP.stack_stages(stacked, 4)
    assert view["w"].shape == (4, 2, 3)
    for s, sub in enumerate(PP.split_stages(stacked, 4)):
        np.testing.assert_array_equal(np.asarray(view["w"][s]),
                                      np.asarray(sub["w"]))


@pytest.mark.parametrize("sched", ["gpipe", "1f1b"])
@pytest.mark.parametrize("pp,n_mb", [(2, 4), (4, 8)])
def test_schedule_masks_cover_ticktable(sched, pp, n_mb):
    """The dense mask arrays feed the jitted executor: one op max per
    (clock, stage); F and B counts each equal n_mb per stage; total clock
    span reproduces the analytic bubble."""
    m = PP.schedule_masks(sched, n_mb, pp)
    assert not (m["do_f"] & m["do_b"]).any()
    assert (m["do_f"].sum(axis=0) == n_mb).all()
    assert (m["do_b"].sum(axis=0) == n_mb).all()
    busy = 2 * n_mb / m["ticks"]
    assert busy == pytest.approx(1 - PP.bubble_fraction(n_mb, pp))


def test_schedule_masks_rejects_unknown_schedule():
    with pytest.raises(ValueError, match="pp_schedule"):
        PP.schedule_masks("interleaved", 4, 2)


def test_parallel_config_validates_pp():
    from repro.configs import ParallelConfig
    with pytest.raises(ValueError, match="pp_schedule"):
        ParallelConfig(pp_schedule="pipedream")
    with pytest.raises(ValueError, match="pp_stages"):
        ParallelConfig(pp_stages=0)
    with pytest.raises(ValueError, match="microbatches"):
        ParallelConfig(microbatches=0)
    assert ParallelConfig(pp_stages=4, pp_schedule="gpipe").pp_stages == 4


# ---------------------------------------------------------------------------
# per-stage executor plumbing: tick-table invariants, wave-balance guardrail,
# analytic per-stage cost attribution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pp,n_mb", [(2, 4), (2, 8), (4, 8), (8, 16)])
def test_tick_tables_agree_on_fb_counts_per_stage(pp, n_mb):
    """gpipe and 1f1b order the work differently but every stage runs
    exactly n_mb forwards and n_mb backwards under both schedules."""
    for sched in ("gpipe", "1f1b"):
        ticks = (PP.gpipe_schedule if sched == "gpipe"
                 else PP.one_f_one_b_schedule)(n_mb, pp)
        for s in range(pp):
            fs = [t for t in ticks if t.stage == s and t.kind == "F"]
            bs = [t for t in ticks if t.stage == s and t.kind == "B"]
            assert len(fs) == len(bs) == n_mb, (sched, s)
            # each microbatch exactly once per direction
            assert sorted(t.mb for t in fs) == list(range(n_mb))
            assert sorted(t.mb for t in bs) == list(range(n_mb))
    # the dense mask tables carry the same counts
    for sched in ("gpipe", "1f1b"):
        m = PP.schedule_masks(sched, n_mb, pp)
        assert m["do_f"].sum(axis=0).tolist() == [n_mb] * pp
        assert m["do_b"].sum(axis=0).tolist() == [n_mb] * pp


def test_check_pp_microbatches_raises_descriptive():
    with pytest.raises(ValueError, match="divisible by pp_stages"):
        PP.check_pp_microbatches(3, 2)
    with pytest.raises(ValueError, match="pp_impl='masked'"):
        PP.check_pp_microbatches(5, 4)       # suggests the fallback
    PP.check_pp_microbatches(8, 4)           # divisible: fine
    PP.check_pp_microbatches(4, 4)


def test_per_stage_executor_requires_pp_mesh():
    with pytest.raises(ValueError, match="mesh with a 'pp' axis"):
        PP.pipelined_loss_and_grads_per_stage(
            None, None, None, {}, {"x": jnp.zeros((2, 1))},
            {"x": jnp.zeros((2, 1))}, {"ce": jnp.zeros((2,))},
            act_shape=(1,), act_dtype=jnp.float32, mesh=None)


def test_per_stage_costs_attribution():
    """masked: every stage pays head+CE; shardmap: only the last stage —
    and the reclaimed compute grows with vocab size."""
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.launch.costmodel import per_stage_costs

    cfg = reduced(get_config("mula-7b-a1b"), layers=4, d_model=64)

    def reclaimed(vocab):
        c = dataclasses.replace(cfg, vocab_size=vocab)
        m = per_stage_costs(c, pp=4, microbatches=8, seq=128,
                            global_batch=16, pp_impl="masked")
        s = per_stage_costs(c, pp=4, microbatches=8, seq=128,
                            global_batch=16, pp_impl="shardmap")
        heads_m = [x["head_gflops"] for x in m["stages"]]
        heads_s = [x["head_gflops"] for x in s["stages"]]
        # masked is uniform and nonzero on every stage
        assert all(h == heads_m[0] > 0 for h in heads_m)
        # per-stage: interior stages pay nothing, last pays less than
        # masked (saved-output backward skips the head recompute)
        assert heads_s[:-1] == [0.0] * 3
        assert 0 < heads_s[-1] < heads_m[-1]
        # block cost stays uniform across stages in both
        assert all(x["block_gflops"] == m["stages"][0]["block_gflops"]
                   for x in m["stages"] + s["stages"])
        return sum(heads_m) - sum(heads_s)

    r512, r8k = reclaimed(512), reclaimed(8192)
    assert 0 < r512 < r8k                    # the win grows with vocab
