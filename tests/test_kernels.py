"""Per-kernel validation vs ref.py oracles (interpret mode) with
shape/dtype sweeps + hypothesis property tests (spec deliverable (c))."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.fixture(autouse=True)
def small_tiles():
    # plan-scoped: restores automatically, nothing leaks across tests
    with ops.use_kernel_plan(dataclasses.replace(ops.current_kernel_plan(),
                                                 tile_m=8)):
        yield


def _groups(rng, G, M, align):
    """Random aligned group sizes summing <= M."""
    cuts = np.sort(rng.integers(0, M // align + 1, size=G - 1)) * align
    sizes = np.diff(np.concatenate([[0], cuts, [M]]))
    return jnp.array(sizes, jnp.int32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("G,M,K,N", [(4, 64, 24, 40), (2, 32, 128, 128),
                                     (8, 128, 16, 8)])
def test_gmm_forward_sweep(dtype, G, M, K, N):
    rng = np.random.default_rng(0)
    gs = _groups(rng, G, M, 8)
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (G, K, N), dtype)
    out = ops.gmm(x, w, gs)
    expect = ref.gmm_ref(x, w, gs)
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(expect, np.float32),
                               atol=tol, rtol=tol)


def test_gmm_gradients_match_ref():
    rng = np.random.default_rng(1)
    gs = _groups(rng, 4, 64, 8)
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 24))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 24, 40))
    g1 = jax.grad(lambda x, w: (ops.gmm(x, w, gs) ** 2).sum(),
                  argnums=(0, 1))(x, w)
    g2 = jax.grad(lambda x, w: (ref.gmm_ref(x, w, gs) ** 2).sum(),
                  argnums=(0, 1))(x, w)
    np.testing.assert_allclose(g1[0], g2[0], atol=1e-3)
    np.testing.assert_allclose(g1[1], g2[1], atol=1e-3)


def test_gmm_empty_group_grad_is_zero():
    gs = jnp.array([0, 32, 0, 32], jnp.int32)
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
    dw = jax.grad(lambda w: (ops.gmm(x, w, gs) ** 2).sum())(w)
    assert np.all(np.isfinite(dw))
    np.testing.assert_allclose(dw[0], 0.0)
    np.testing.assert_allclose(dw[2], 0.0)


@pytest.mark.parametrize("T,K,D", [(32, 2, 48), (64, 8, 16), (16, 1, 512)])
def test_combine_kernel(T, K, D):
    rows = jax.random.normal(jax.random.PRNGKey(0), (T, K, D))
    w = jax.random.normal(jax.random.PRNGKey(1), (T, K))
    np.testing.assert_allclose(ops.combine(rows, w),
                               ref.combine_ref(rows, w), atol=1e-4)
    g1 = jax.grad(lambda r, w: (ops.combine(r, w) ** 2).sum(),
                  argnums=(0, 1))(rows, w)
    g2 = jax.grad(lambda r, w: (ref.combine_ref(r, w) ** 2).sum(),
                  argnums=(0, 1))(rows, w)
    np.testing.assert_allclose(g1[0], g2[0], atol=1e-3)
    np.testing.assert_allclose(g1[1], g2[1], atol=1e-3)


def test_combine_bwd_matches_paper_formulas():
    """Stage 5 backward (paper lines 98-113): explicit formula check."""
    rows = jax.random.normal(jax.random.PRNGKey(0), (8, 2, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 2))
    dout = jax.random.normal(jax.random.PRNGKey(2), (8, 16))
    _, vjp = jax.vjp(ops.combine, rows, w)
    drows, dw = vjp(dout)
    drows_ref, dw_ref = ref.combine_bwd_ref(rows, w, dout)
    np.testing.assert_allclose(drows, drows_ref, atol=1e-5)
    np.testing.assert_allclose(dw, dw_ref, atol=1e-5)


@pytest.mark.parametrize("M,N", [(32, 48), (8, 512), (128, 16)])
def test_swiglu_kernel(M, N):
    g = jax.random.normal(jax.random.PRNGKey(0), (M, N))
    u = jax.random.normal(jax.random.PRNGKey(1), (M, N))
    np.testing.assert_allclose(ops.fused_swiglu(g, u), ref.swiglu_ref(g, u),
                               atol=1e-5)
    s1 = jax.grad(lambda g, u: (ops.fused_swiglu(g, u) ** 2).sum(),
                  argnums=(0, 1))(g, u)
    s2 = jax.grad(lambda g, u: (ref.swiglu_ref(g, u) ** 2).sum(),
                  argnums=(0, 1))(g, u)
    np.testing.assert_allclose(s1[0], s2[0], atol=1e-4)
    np.testing.assert_allclose(s1[1], s2[1], atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 500), st.integers(1, 16), st.integers(0, 20))
def test_token_counts_property(n, e, off):
    """Histogram == bincount for arbitrary index streams/offsets."""
    idx = jax.random.randint(jax.random.PRNGKey(n), (n,), 0, e + off + 3)
    got = ops.token_counts(idx, e, off)
    expect = ref.token_counts_ref(idx, e, off)
    assert np.array_equal(np.array(got), np.array(expect))
    assert int(got.sum()) <= n


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(2, 8))
def test_gmm_matches_blockdiag_property(G, nblk):
    """gmm == block-diagonal dense matmul for any aligned group layout."""
    rng = np.random.default_rng(G * 31 + nblk)
    M = nblk * 8 * G
    gs = _groups(rng, G, M, 8)
    x = jax.random.normal(jax.random.PRNGKey(0), (M, 12))
    w = jax.random.normal(jax.random.PRNGKey(1), (G, 12, 20))
    np.testing.assert_allclose(ops.gmm(x, w, gs), ref.gmm_ref(x, w, gs),
                               atol=1e-4)
