"""Mamba-2 SSD intra-chunk Pallas kernel vs the jnp chunked-scan oracle,
standalone and composed into the full sequence scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import ssd_intra_chunk
from repro.models.ssm import _ssd_chunked


def _rand(B, C, L, H, P, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, C, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, C, L, H)))
    Bm = jax.random.normal(ks[2], (B, C, L, N))
    Cm = jax.random.normal(ks[3], (B, C, L, N))
    A = -jnp.exp(jax.random.normal(ks[4], (H,)))
    return x, dt, Bm, Cm, A


@pytest.mark.parametrize("B,C,L,H,P,N", [(2, 3, 16, 4, 8, 8),
                                         (1, 2, 32, 2, 16, 4),
                                         (2, 1, 8, 8, 4, 16)])
def test_ssd_kernel_matches_oracle(B, C, L, H, P, N):
    x, dt, Bm, Cm, A = _rand(B, C, L, H, P, N)
    y, st, cd = ssd_intra_chunk(x, dt, Bm, Cm, A)

    la = jnp.cumsum(dt * A, axis=2)
    seg = la[:, :, :, None] - la[:, :, None, :]
    causal = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)
    dtx = dt[..., None] * x
    np.testing.assert_allclose(
        y, jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, decay, dtx), atol=1e-4)
    w = jnp.exp(la[:, :, -1:, :] - la)
    np.testing.assert_allclose(
        st, jnp.einsum("bcjh,bcjhp,bcjn->bchpn", w, dtx, Bm), atol=1e-4)
    np.testing.assert_allclose(cd, jnp.exp(la[:, :, -1, :]), atol=1e-5)


def test_ssd_kernel_composes_to_full_scan():
    """Kernel intra-chunk outputs + the jnp inter-chunk recurrence ==
    the reference full chunked scan (and hence the naive recurrence)."""
    B, S, H, P, N, Lc = 2, 48, 4, 8, 8, 16
    C = S // Lc
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (H,)))

    y_ref, h_ref = _ssd_chunked(x, dt, Bm, Cm, A, Lc)

    xb = x.reshape(B, C, Lc, H, P)
    dtb = dt.reshape(B, C, Lc, H)
    Bb = Bm.reshape(B, C, Lc, N)
    Cb = Cm.reshape(B, C, Lc, N)
    y_diag, states, cdecay = ssd_intra_chunk(xb, dtb, Bb, Cb, A)

    la = jnp.cumsum(dtb * A, axis=2)

    def step(h, c):
        y_off_c = jnp.einsum("bin,bih,bhpn->bihp", Cb[:, c],
                             jnp.exp(la[:, c]), h)
        h = cdecay[:, c][..., None, None] * h + states[:, c]
        return h, y_off_c

    h0 = jnp.zeros((B, H, P, N))
    h_last, y_off = jax.lax.scan(step, h0, jnp.arange(C))
    y = (y_diag + y_off.transpose(1, 0, 2, 3, 4)).reshape(B, S, H, P)
    np.testing.assert_allclose(y, y_ref, atol=2e-4)
    np.testing.assert_allclose(h_last, h_ref, atol=2e-4)
