"""SO vs EPSO optimizer-state sharding (paper §3.2) — spec-level properties
checked on an abstract mesh (no devices needed beyond CPU)."""
import jax
import pytest
from jax.sharding import AbstractMesh, AxisType, PartitionSpec as P

from repro.configs import get_config
from repro.models import init_params
from repro.optim.epso import optimizer_state_specs, state_bytes_per_device
from repro.parallel.sharding import make_rules


def abstract_mesh(multi_pod=False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return AbstractMesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_config("mula-20b-a2b")
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    mesh = abstract_mesh()
    rules = make_rules(cfg, mesh, kind="train", global_batch=256)
    return cfg, shapes, mesh, rules


def _axes_used(spec):
    out = set()
    for e in spec:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a is not None:
                out.add(a)
    return out


def test_epso_shards_nonexpert_states_over_model(moe_setup):
    """The paper's core claim: under EP, SO leaves non-expert states
    replicated over the EP axis; EPSO shards them DPxEP ways."""
    cfg, shapes, mesh, rules = moe_setup
    so = optimizer_state_specs(shapes, rules, "so")
    epso = optimizer_state_specs(shapes, rules, "epso")
    # attention weight: non-expert -> replicated over model in SO
    attn_so = so["layers"]["attn"]["wq"]
    attn_epso = epso["layers"]["attn"]["wq"]
    assert "model" not in _axes_used(attn_so)
    assert "model" in _axes_used(attn_epso)
    assert "data" in _axes_used(attn_epso)
    # expert weights: already model-sharded in both; EPSO adds data sharding
    exp_epso = epso["layers"]["moe"]["gate"]
    assert {"model", "data"} <= _axes_used(exp_epso)


def test_epso_reduces_state_bytes(moe_setup):
    """Figure 6 counterpart: per-device optimizer bytes shrink under EPSO."""
    cfg, shapes, mesh, rules = moe_setup
    so = state_bytes_per_device(shapes, rules, "so")
    epso = state_bytes_per_device(shapes, rules, "epso")
    assert epso < so
    # non-expert params are a minority in a 20B MoE, but the win must be
    # at least the EP-fold shrink of the non-expert share
    total = sum(l.size for l in jax.tree.leaves(shapes))
    expert = sum(l.size for l in jax.tree.leaves(shapes["layers"]["moe"])
                 if l.ndim == 4)     # stacked (L, E, d, f)
    nonexpert = total - expert
    # SO: nonexpert states replicated over model (16x waste)
    predicted_save = nonexpert * 12 * (1 / 16 - 1 / 256)
    assert so - epso >= 0.5 * abs(predicted_save)


def test_specs_always_divisible(moe_setup):
    """Every sharded dim must divide by its mesh axes (else XLA rejects)."""
    cfg, shapes, mesh, rules = moe_setup
    for mode in ("so", "epso"):
        specs = optimizer_state_specs(shapes, rules, mode)

        def check(spec, leaf):
            for i, e in enumerate(spec):
                n = 1
                for a in (e if isinstance(e, tuple) else (e,)):
                    if a is not None:
                        n *= mesh.shape[a]
                assert leaf.shape[i] % n == 0, (mode, spec, leaf.shape)

        jax.tree.map(check, specs, shapes,
                     is_leaf=lambda x: isinstance(x, P))


def test_epso_on_dense_arch_uses_model_axis_too():
    """EPSO generalizes: dense-TP replicated params (norms) gain sharding."""
    cfg = get_config("deepseek-7b")
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    rules = make_rules(cfg, abstract_mesh(), kind="train", global_batch=256)
    epso = optimizer_state_specs(shapes, rules, "epso")
    norm = epso["layers"]["ln1"]["scale"]       # (L, d) stacked: d=4096
    assert _axes_used(norm) & {"data", "model"}


def test_multi_pod_specs(moe_setup):
    cfg, shapes, _, _ = moe_setup
    mesh = abstract_mesh(multi_pod=True)
    rules = make_rules(cfg, mesh, kind="train", global_batch=512)
    epso = optimizer_state_specs(shapes, rules, "epso")
    used = _axes_used(epso["layers"]["attn"]["wq"])
    assert "pod" in used or "data" in used


# ---------------------------------------------------------------------------
# SO/EPSO parity: placement must not change the math (ISSUE 2 satellite)
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.slow
def test_so_epso_parity_and_bytes(mesh8):
    """Identical seeds/batches under mode='so' vs 'epso' give allclose losses
    and params for 10 steps on a (4,2) mesh; epso strictly beats so on
    per-device state bytes (the model axis is nontrivial)."""
    out = mesh8("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ParallelConfig, TrainConfig, get_config, reduced
        from repro.optim.epso import state_bytes_per_device
        from repro.parallel.plan import ParallelPlan
        from repro.train import init_state, make_train_step

        cfg = reduced(get_config("mula-7b-a1b"), d_model=64)
        tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                         grad_reduce_dtype="float32", lr_peak=1e-3,
                         lr_min=1e-4, warmup_steps=2, total_steps=10,
                         seq_len=32, global_batch=8)
        batches = []
        for s in range(10):
            t = jax.random.randint(jax.random.PRNGKey(100 + s), (8, 33), 0,
                                   cfg.vocab_size)
            batches.append({"tokens": t[:, :-1], "labels": t[:, 1:]})
        results = {}
        for mode in ("so", "epso"):
            plan = ParallelPlan.from_legacy("4,2", cfg=cfg, opt_shard=mode) \
                .resolve(cfg, global_batch=8)
            state = init_state(jax.random.PRNGKey(0), cfg, tc, plan=plan)
            fn = make_train_step(cfg, ParallelConfig(), tc, plan=plan)
            losses = []
            for b in batches:
                state, m = fn(state, b)
                losses.append(float(m["loss"]))
            results[mode] = (state, losses, plan.rules)
        lso, lep = results["so"][1], results["epso"][1]
        assert np.allclose(lso, lep, rtol=1e-5), (lso, lep)
        for a, b in zip(jax.tree.leaves(results["so"][0].params),
                        jax.tree.leaves(results["epso"][0].params)):
            assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        params = results["so"][0].params
        rules = results["so"][2]
        so_b = state_bytes_per_device(params, rules, "so")
        ep_b = state_bytes_per_device(params, rules, "epso")
        assert ep_b < so_b, (ep_b, so_b)
        print("OK", so_b, ep_b)
    """)
    assert "OK" in out


# ---------------------------------------------------------------------------
# property tests for epso._augment (hypothesis / deterministic stub)
# ---------------------------------------------------------------------------

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.optim.epso import _augment  # noqa: E402

_PROP_MESHES = [
    ((16, 16), ("data", "model")),
    ((2, 4), ("data", "model")),
    ((4, 2), ("data", "model")),
    ((8, 1), ("data", "model")),
    ((1, 8), ("data", "model")),
    ((8,), ("data",)),
    ((2, 2, 2), ("pod", "data", "model")),
    ((2, 4, 4), ("pod", "data", "model")),
]


def _prop_mesh(i):
    shape, axes = _PROP_MESHES[i]
    return AbstractMesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def _entry_axes(e):
    return tuple(a for a in (e if isinstance(e, tuple) else (e,))
                 if a is not None)


def _base_spec(mesh, shape, choice):
    """A valid param-style base spec: replicated, or 'model' on the first
    dim that divides it (mirrors what param_specs produces)."""
    options = [P()]
    if "model" in mesh.shape:
        n = mesh.shape["model"]
        for i, d in enumerate(shape):
            if d % n == 0 and n > 1:
                options.append(P(*([None] * i + ["model"])))
                break
    return options[choice % len(options)]


@settings(max_examples=80, deadline=None)
@given(st.integers(0, len(_PROP_MESHES) - 1),
       st.lists(st.sampled_from([1, 2, 3, 4, 6, 8, 16, 17, 24, 32, 64]),
                min_size=1, max_size=3),
       st.integers(0, 3))
def test_augment_properties(mesh_i, shape, spec_choice):
    mesh = _prop_mesh(mesh_i)
    shape = tuple(shape)
    base = _base_spec(mesh, shape, spec_choice)
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    group = dp + (("model",) if "model" in mesh.shape else ())
    aug = _augment(base, shape, [group], mesh)

    assert len(aug) <= len(shape)
    # 1) never double-uses a mesh axis
    used = [a for e in aug for a in _entry_axes(e)]
    assert len(used) == len(set(used)), (aug, shape)
    # 2) base sharding is preserved (augment only adds)
    for i, e in enumerate(base):
        for a in _entry_axes(e):
            assert a in _entry_axes(aug[i]) or aug[i] == e, (base, aug)
    # 3) every named axis divides its dim
    for i, e in enumerate(aug):
        n = 1
        for a in _entry_axes(e):
            assert a in mesh.shape
            n *= mesh.shape[a]
        assert shape[i] % n == 0, (aug, shape, mesh.shape)
    # 4) leaves too small to divide stay replicated: if no unsharded dim is
    #    divisible by any size>1 axis of the group, the spec is unchanged
    base_axes = {a for e in base for a in _entry_axes(e)}
    remaining = [a for a in group if a not in base_axes]
    base_entries = list(base) + [None] * (len(shape) - len(base))
    divisible = any(
        base_entries[i] is None
        and any(mesh.shape[a] > 1 and shape[i] % mesh.shape[a] == 0
                for a in remaining)
        for i in range(len(shape)))
    if not divisible:
        assert aug == base, (base, aug, shape)


def test_augment_dedupes_repeated_axis_in_group():
    """Regression: a group naming the same axis twice must not emit an XLA-
    invalid spec like P(('data','data')) via the group-splitting fallback."""
    mesh = _prop_mesh(2)                       # (4,2) data,model
    aug = _augment(P(), (16,), [("data", "data")], mesh)
    used = [a for e in aug for a in _entry_axes(e)]
    assert len(used) == len(set(used)), aug
    assert aug == P("data")


@settings(max_examples=60, deadline=None)
@given(st.integers(0, len(_PROP_MESHES) - 1),
       st.lists(st.sampled_from([1, 2, 3, 4, 6, 8, 16, 17, 24, 32, 64]),
                min_size=1, max_size=3),
       st.lists(st.sampled_from(["pod", "data", "model", "data", "model"]),
                min_size=1, max_size=5))
def test_augment_adversarial_groups(mesh_i, shape, group):
    """Same validity properties under hostile groups: repeated axes, axes
    absent from the mesh, arbitrary order."""
    mesh = _prop_mesh(mesh_i)
    shape = tuple(shape)
    aug = _augment(P(), shape, [tuple(group)], mesh)
    assert len(aug) <= len(shape)
    used = [a for e in aug for a in _entry_axes(e)]
    assert len(used) == len(set(used)), (aug, group)
    for i, e in enumerate(aug):
        n = 1
        for a in _entry_axes(e):
            assert a in mesh.shape, (aug, group)
            n *= mesh.shape[a]
        assert shape[i] % n == 0, (aug, shape, mesh.shape)
