"""SO vs EPSO optimizer-state sharding (paper §3.2) — spec-level properties
checked on an abstract mesh (no devices needed beyond CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, AxisType, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.optim.epso import optimizer_state_specs, state_bytes_per_device
from repro.parallel.sharding import make_rules


def abstract_mesh(multi_pod=False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return AbstractMesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_config("mula-20b-a2b")
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    mesh = abstract_mesh()
    rules = make_rules(cfg, mesh, kind="train", global_batch=256)
    return cfg, shapes, mesh, rules


def _axes_used(spec):
    out = set()
    for e in spec:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a is not None:
                out.add(a)
    return out


def test_epso_shards_nonexpert_states_over_model(moe_setup):
    """The paper's core claim: under EP, SO leaves non-expert states
    replicated over the EP axis; EPSO shards them DPxEP ways."""
    cfg, shapes, mesh, rules = moe_setup
    so = optimizer_state_specs(shapes, rules, "so")
    epso = optimizer_state_specs(shapes, rules, "epso")
    # attention weight: non-expert -> replicated over model in SO
    attn_so = so["layers"]["attn"]["wq"]
    attn_epso = epso["layers"]["attn"]["wq"]
    assert "model" not in _axes_used(attn_so)
    assert "model" in _axes_used(attn_epso)
    assert "data" in _axes_used(attn_epso)
    # expert weights: already model-sharded in both; EPSO adds data sharding
    exp_epso = epso["layers"]["moe"]["gate"]
    assert {"model", "data"} <= _axes_used(exp_epso)


def test_epso_reduces_state_bytes(moe_setup):
    """Figure 6 counterpart: per-device optimizer bytes shrink under EPSO."""
    cfg, shapes, mesh, rules = moe_setup
    so = state_bytes_per_device(shapes, rules, "so")
    epso = state_bytes_per_device(shapes, rules, "epso")
    assert epso < so
    # non-expert params are a minority in a 20B MoE, but the win must be
    # at least the EP-fold shrink of the non-expert share
    total = sum(l.size for l in jax.tree.leaves(shapes))
    expert = sum(l.size for l in jax.tree.leaves(shapes["layers"]["moe"])
                 if l.ndim == 4)     # stacked (L, E, d, f)
    nonexpert = total - expert
    # SO: nonexpert states replicated over model (16x waste)
    predicted_save = nonexpert * 12 * (1 / 16 - 1 / 256)
    assert so - epso >= 0.5 * abs(predicted_save)


def test_specs_always_divisible(moe_setup):
    """Every sharded dim must divide by its mesh axes (else XLA rejects)."""
    cfg, shapes, mesh, rules = moe_setup
    for mode in ("so", "epso"):
        specs = optimizer_state_specs(shapes, rules, mode)

        def check(spec, leaf):
            for i, e in enumerate(spec):
                n = 1
                for a in (e if isinstance(e, tuple) else (e,)):
                    if a is not None:
                        n *= mesh.shape[a]
                assert leaf.shape[i] % n == 0, (mode, spec, leaf.shape)

        jax.tree.map(check, specs, shapes,
                     is_leaf=lambda x: isinstance(x, P))


def test_epso_on_dense_arch_uses_model_axis_too():
    """EPSO generalizes: dense-TP replicated params (norms) gain sharding."""
    cfg = get_config("deepseek-7b")
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    rules = make_rules(cfg, abstract_mesh(), kind="train", global_batch=256)
    epso = optimizer_state_specs(shapes, rules, "epso")
    norm = epso["layers"]["ln1"]["scale"]       # (L, d) stacked: d=4096
    assert _axes_used(norm) & {"data", "model"}


def test_multi_pod_specs(moe_setup):
    cfg, shapes, _, _ = moe_setup
    mesh = abstract_mesh(multi_pod=True)
    rules = make_rules(cfg, mesh, kind="train", global_batch=512)
    epso = optimizer_state_specs(shapes, rules, "epso")
    used = _axes_used(epso["layers"]["attn"]["wq"])
    assert "pod" in used or "data" in used
