"""AdamW math, schedule, clipping, and SO/EPSO sharding-spec properties."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim import adamw_init, adamw_update, warmup_cosine
from repro.optim.adamw import global_norm


def test_adamw_matches_reference_math():
    """One step vs a literal numpy AdamW."""
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st_ = adamw_init(p)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.99, 1e-8, 0.1
    newp, st2, m = adamw_update(g, st_, lr=lr, beta1=b1, beta2=b2, eps=eps,
                                weight_decay=wd, grad_clip=0)
    gn = np.array(g["w"], np.float64)
    mm = (1 - b1) * gn
    vv = (1 - b2) * gn ** 2
    mhat = mm / (1 - b1)
    vhat = vv / (1 - b2)
    expect = np.array(p["w"]) - lr * (mhat / (np.sqrt(vhat) + eps)
                                      + wd * np.array(p["w"]))
    np.testing.assert_allclose(np.array(newp["w"]), expect, rtol=1e-6)
    assert int(st2.step) == 1


def test_grad_clip_only_after_warmup():
    """Paper recipe: clipping applies only after warmup."""
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}     # huge grads
    st_ = adamw_init(p)
    _, _, m_warm = adamw_update(g, st_, lr=1e-3, grad_clip=1.0,
                                clip_enabled=jnp.array(False))
    _, _, m_post = adamw_update(g, st_, lr=1e-3, grad_clip=1.0,
                                clip_enabled=jnp.array(True))
    assert float(m_warm["clip_scale"]) == 1.0
    assert float(m_post["clip_scale"]) < 0.01


def test_schedule_shape():
    lrs = [float(warmup_cosine(s, lr_peak=4e-4, lr_min=4e-5,
                               warmup_steps=100, total_steps=1000))
           for s in [0, 50, 100, 500, 1000]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 2e-4) < 1e-6          # mid-warmup
    assert abs(lrs[2] - 4e-4) < 1e-5          # peak
    assert lrs[3] < lrs[2]                    # decaying
    assert abs(lrs[4] - 4e-5) < 1e-6          # floor


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 6))
def test_global_norm_property(seed):
    tree = {"a": jax.random.normal(jax.random.PRNGKey(seed), (7,)),
            "b": jax.random.normal(jax.random.PRNGKey(seed + 1), (3, 5))}
    flat = np.concatenate([np.ravel(tree["a"]), np.ravel(tree["b"])])
    np.testing.assert_allclose(float(global_norm(tree)),
                               np.linalg.norm(flat), rtol=1e-5)


def test_training_reduces_loss_on_fixed_batch():
    """integration: memorize one batch."""
    from repro.configs import TrainConfig, ParallelConfig, get_config, reduced
    from repro.train import init_state, make_train_step
    cfg = reduced(get_config("deepseek-7b"), d_model=64)
    tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                     grad_reduce_dtype="float32", warmup_steps=5,
                     total_steps=100, lr_peak=2e-3, lr_min=1e-4)
    state = init_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, ParallelConfig(), tc))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    first = None
    for i in range(25):
        state, m = step(state, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first - 1.0
