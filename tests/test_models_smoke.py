"""Per-architecture smoke tests (spec deliverable (f)): a REDUCED variant of
each assigned family (2 layers, d_model<=512, <=4 experts) runs one forward
+ one train step on CPU, asserting output shapes + no NaNs; decode shapes
additionally round-trip a serve_step."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ASSIGNED_ARCHS, ParallelConfig, TrainConfig,
                           get_config, reduced)
from repro.models import (init_params, forward, loss_fn, init_cache,
                          decode_step, padded_vocab)
from repro.train import init_state, make_train_step

# warmup_steps=0: linear warmup gives lr=0 at step 0, which would make the
# "params changed" assertion vacuous on the very first step
TC = TrainConfig(param_dtype="float32", compute_dtype="float32",
                 grad_reduce_dtype="float32", warmup_steps=0, total_steps=50,
                 lr_peak=1e-3, lr_min=1e-4)


def make_batch(cfg, B=2, S=32, seed=0):
    rng = jax.random.PRNGKey(seed)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = jax.random.normal(
            rng, (B, cfg.num_prefix_embeds, cfg.d_model))
    if cfg.arch_type == "audio":
        batch["frame_embeds"] = jax.random.normal(rng, (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch), d_model=128)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    params = init_params(jax.random.PRNGKey(0), cfg)

    logits, aux = forward(params, batch, cfg, compute_dtype=jnp.float32,
                          sac="")
    S_out = S + (cfg.num_prefix_embeds if cfg.arch_type == "vlm" else 0)
    assert logits.shape == (B, S_out, padded_vocab(cfg))
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    state = init_state(jax.random.PRNGKey(0), cfg, TC)
    step = jax.jit(make_train_step(cfg, ParallelConfig(), TC))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         state.params, state2.params)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_serve_step(arch):
    cfg = reduced(get_config(arch), d_model=128)
    B = 2
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, B, 16, jnp.float32)
    if cfg.arch_type == "audio":
        cache["memory"] = jax.random.normal(jax.random.PRNGKey(1),
                                            (B, 16, cfg.d_model))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, t, c: decode_step(p, t, c, 0, cfg,
                                    compute_dtype=jnp.float32))(params, tok,
                                                                cache)
    assert logits.shape == (B, 1, padded_vocab(cfg))
    assert bool(jnp.isfinite(logits).all())
    # cache updated in place-shape
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["deepseek-7b", "zamba2-7b",
                                  "falcon-mamba-7b", "mixtral-8x7b"])
def test_decode_matches_forward(arch):
    """Teacher-forced forward logits == step-by-step decode (RoPE, ring
    buffers, SSM states)."""
    cfg = reduced(get_config(arch), d_model=64)
    if cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=8)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    p = init_params(jax.random.PRNGKey(0), cfg)
    ref, _ = forward(p, {"tokens": toks, "labels": toks}, cfg,
                     compute_dtype=jnp.float32, sac="")
    cache = init_cache(cfg, B, S, jnp.float32)
    step = jax.jit(lambda p, t, c, i: decode_step(p, t, c, i, cfg,
                                                  compute_dtype=jnp.float32))
    outs = []
    for i in range(S):
        lg, cache = step(p, toks[:, i:i + 1], cache, i)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.array(dec), np.array(ref), atol=5e-3)


def test_sac_policies_equivalent():
    """SAC changes memory, not math: losses identical across policies."""
    cfg = reduced(get_config("mixtral-8x7b"), d_model=64)
    batch = make_batch(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    losses = []
    for sac in ("", "block", "attn", "moe", "attn,moe"):
        loss, _ = loss_fn(params, batch, cfg, sac=sac,
                          compute_dtype=jnp.float32)
        losses.append(float(loss))
    np.testing.assert_allclose(losses, losses[0], rtol=1e-6)


def test_vlm_loss_masks_image_prefix():
    cfg = reduced(get_config("phi-3-vision-4.2b"), d_model=64)
    batch = make_batch(cfg)
    loss, metrics = loss_fn(init_params(jax.random.PRNGKey(0), cfg), batch,
                            cfg, compute_dtype=jnp.float32)
    # ntok counts only text labels
    assert int(metrics["ntok"]) == batch["labels"].size


def test_microbatched_train_step_matches_single():
    cfg = reduced(get_config("deepseek-7b"), d_model=64)
    batch = make_batch(cfg, B=4)
    state = init_state(jax.random.PRNGKey(0), cfg, TC)
    s1, m1 = jax.jit(make_train_step(cfg, ParallelConfig(microbatches=1),
                                     TC))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, ParallelConfig(microbatches=2),
                                     TC))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(a, b, atol=2e-5)
