"""Data pipeline (paper §4): tokenize -> shuffle -> shard -> mmap loading."""

import numpy as np
import pytest

from repro.data import ByteTokenizer, ShardedDataLoader, preprocess_corpus


@pytest.fixture
def corpus():
    rng = np.random.default_rng(0)
    return [[f"document {i}-{j} " + "x" * int(rng.integers(10, 90))
             for j in range(20)] for i in range(3)]


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "hello Aurora 🙂"
    assert tok.decode(tok.encode(s)) == s


def test_preprocess_deterministic(tmp_path, corpus):
    m1 = preprocess_corpus(corpus, str(tmp_path / "a"), context=32, seed=7)
    m2 = preprocess_corpus(corpus, str(tmp_path / "b"), context=32, seed=7)
    a = np.load(tmp_path / "a" / m1["shards"][0])
    b = np.load(tmp_path / "b" / m2["shards"][0])
    assert np.array_equal(a, b)
    m3 = preprocess_corpus(corpus, str(tmp_path / "c"), context=32, seed=8)
    c = np.load(tmp_path / "c" / m3["shards"][0])
    assert not np.array_equal(a, c)          # different shuffle


def test_instances_cover_corpus_once(tmp_path, corpus):
    """The shuffle is a permutation: every instance appears exactly once."""
    meta = preprocess_corpus(corpus, str(tmp_path / "d"), context=16, seed=0,
                             shard_instances=7)
    loaded = np.concatenate([np.load(tmp_path / "d" / s)
                             for s in meta["shards"]])
    assert loaded.shape == (meta["num_instances"], 17)
    # rebuild unshuffled instances and compare as multisets of rows
    from repro.data.preprocess import tokenize_files
    step = 17
    rows = []
    for t in tokenize_files(corpus):
        n = len(t) // step
        rows.append(t[:n * step].reshape(n, step))
    ref = np.concatenate(rows)
    assert sorted(map(tuple, loaded.tolist())) == sorted(map(tuple,
                                                             ref.tolist()))


def test_loader_contiguous_dp_reads(tmp_path, corpus):
    """DP ranks read disjoint contiguous slices covering each step's batch."""
    preprocess_corpus(corpus, str(tmp_path / "e"), context=16, seed=0,
                      shard_instances=5)
    full = ShardedDataLoader(str(tmp_path / "e"), global_batch=8)
    parts = [ShardedDataLoader(str(tmp_path / "e"), global_batch=8,
                               dp_rank=r, dp_size=4) for r in range(4)]
    for step in (0, 1, full.steps_per_epoch - 1):
        whole = full.batch(step)["tokens"]
        stitched = np.concatenate([p.batch(step)["tokens"] for p in parts])
        assert np.array_equal(whole, stitched)


def test_loader_mmap_mode(tmp_path, corpus):
    meta = preprocess_corpus(corpus, str(tmp_path / "f"), context=16, seed=0)
    dl = ShardedDataLoader(str(tmp_path / "f"), global_batch=4)
    assert isinstance(dl._mmaps[0], np.memmap)   # lazy mmap loading
    b = dl.batch(0)
    assert b["tokens"].shape == (4, 16)
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_loader_resume_replays_exact_batch_sequence(tmp_path, corpus):
    """Fault-tolerant resume: a loader restarted via start_step /
    load_state_dict serves the same batches an uninterrupted iterator would
    — never batch 0 again."""
    preprocess_corpus(corpus, str(tmp_path / "g"), context=16, seed=0)
    straight = ShardedDataLoader(str(tmp_path / "g"), global_batch=4)
    it = iter(straight)
    ref = [next(it) for _ in range(6)]
    assert straight.state_dict() == {"step": 6}

    resumed = ShardedDataLoader(str(tmp_path / "g"), global_batch=4)
    it2 = iter(resumed)
    for _ in range(3):
        next(it2)                                 # "crash" after step 2
    resumed2 = ShardedDataLoader(str(tmp_path / "g"), global_batch=4)
    resumed2.load_state_dict(resumed.state_dict())
    it3 = iter(resumed2)
    for k in range(3, 6):
        b = next(it3)
        assert np.array_equal(b["tokens"], ref[k]["tokens"]), k

    # start_step in the constructor is equivalent
    fresh = ShardedDataLoader(str(tmp_path / "g"), global_batch=4,
                              start_step=4)
    assert np.array_equal(next(iter(fresh))["tokens"], ref[4]["tokens"])
