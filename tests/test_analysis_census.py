"""Shardlint layer 1: sharding contracts, the HLO/jaxpr census, and the
ANALYSIS_census.json regression gate.

The acceptance test for the whole pipeline is the *injection* test: take
the committed EPSO census entry, splice in a full-parameter all-gather
(the PR 7 regression's structural signature), and the contract machinery
must flag it BY NAME ("epso-no-full-param-gather") — no step-time
measurement involved.
"""
import copy
import json
import os
import sys

import pytest

from repro.analysis import census as C
from repro.analysis import contracts as K
from repro.parallel.plan import ParallelPlan

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "benchmarks"))
import check_regression as CR  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(ROOT, "ANALYSIS_census.json")


@pytest.fixture(scope="module")
def baseline():
    with open(BASELINE) as f:
        return json.load(f)


def _entry(baseline, frag):
    for e in baseline["census_points"]:
        if frag in e["spec"]:
            return copy.deepcopy(e)
    raise AssertionError(f"no census entry matching {frag!r}")


# --- the committed baseline is self-consistent ----------------------------

def test_baseline_covers_matrix_and_is_clean(baseline):
    specs = [e["spec"] for e in baseline["census_points"]]
    assert specs == [str(ParallelPlan.parse(s)) for s in C.MATRIX]
    for e in baseline["census_points"]:
        assert e["violations"] == [], e["spec"]
        # re-running the contracts on the stored entry reproduces clean
        assert K.violations(e) == [], e["spec"]
        assert list(e["contracts"]) == \
            list(ParallelPlan.parse(e["spec"]).contracts())


# --- injection: the PR 7 regression, expressed structurally ---------------

def test_injected_full_param_gather_flagged_by_name(baseline):
    """A deliberately-introduced full-param all-gather in the EPSO step is
    flagged by contract id, naming the plan."""
    e = _entry(baseline, "opt=epso,overlap=ring")
    assert K.violations(e) == []                   # clean before injection
    e["max_payload"]["all-gather"] = e["full_param_bytes"]
    msgs = K.violations(e)
    assert len(msgs) == 1
    assert msgs[0].startswith("epso-no-full-param-gather:")
    assert e["spec"] in msgs[0]
    # one byte under the full-param payload is still legal (bucketed
    # shard movement can approach but never reach the full gather)
    e["max_payload"]["all-gather"] = e["full_param_bytes"] - 1
    assert K.violations(e) == []


def test_injected_ragged_dot_in_auto_context(baseline):
    e = _entry(baseline, "tp=2,opt=epso,overlap=off")
    e["jaxpr_prims"]["ragged_dot"] = 2
    msgs = K.violations(e)
    assert any(m.startswith("no-gspmd-ragged-dot:") for m in msgs)
    # inside a manual (shard_map) region the same primitive is fine
    del e["jaxpr_prims"]["ragged_dot"]
    e["jaxpr_prims"]["ragged_dot/manual"] = 2
    assert K.violations(e) == []


def test_injected_host_transfer(baseline):
    e = _entry(baseline, "dp=8")
    e["host_transfers"] = ["outfeed"]
    e["jaxpr_prims"]["pure_callback"] = 1
    msgs = K.violations(e)
    assert sum(m.startswith("no-host-transfer:") for m in msgs) == 2


def test_costmodel_divergence_both_directions(baseline):
    e = _entry(baseline, "dp=8")
    analytic = e["analytic_total"]
    e["ring_bytes"]["total"] = analytic * (K.COSTMODEL_TOLERANCE + 1)
    assert any(m.startswith("coll-vs-costmodel:")
               for m in K.violations(e))
    e["ring_bytes"]["total"] = analytic / (K.COSTMODEL_TOLERANCE + 1)
    assert any(m.startswith("coll-vs-costmodel:")
               for m in K.violations(e))


def test_check_entry_rejects_unknown_contract(baseline):
    e = _entry(baseline, "dp=8")
    with pytest.raises(KeyError, match="unknown sharding contract"):
        K.check_entry(e, ids=["no-such-contract"])


# --- ParallelPlan.contracts(): the plan declares its own invariants -------

@pytest.mark.parametrize("spec,expected", [
    ("dp=8", ("no-host-transfer", "coll-vs-costmodel")),
    ("dp=1", ("no-host-transfer",)),
    ("dp=2,ep=2,tp=2,opt=epso",
     ("no-host-transfer", "coll-vs-costmodel", "no-gspmd-ragged-dot",
      "epso-no-full-param-gather")),
    ("dp=4,tp=2",
     ("no-host-transfer", "coll-vs-costmodel", "no-gspmd-ragged-dot")),
])
def test_plan_contracts(spec, expected):
    assert ParallelPlan.parse(spec).contracts() == expected
    for cid in expected:
        assert cid in K.CONTRACTS


# --- hlo_census over synthetic HLO ----------------------------------------

HLO_SNIPPET = """\
HloModule census_fixture
ENTRY main {
  ag = f32[256]{0} all-gather(p0), replica_groups={{0,1,2,3}}, dimensions={0}
  ar-start = f32[64]{0} all-reduce-start(p1), replica_groups={{0,1}}, to_apply=add
  ar-done = f32[64]{0} all-reduce-done(ar-start)
  out = f32[8]{0} outfeed(tok), outfeed_config=""
  cc = f32[4]{0} custom-call(p2), custom_call_target="xla_python_cpu_callback"
  topk = (f32[8]{0}, s32[8]{0}) custom-call(p3), custom_call_target="TopK"
}
"""


def test_hlo_census_counts_bytes_and_host_transfers():
    cen = C.hlo_census(HLO_SNIPPET)
    assert cen["counts"]["all-gather"] == 1
    assert cen["counts"]["all-reduce"] == 1        # start/done pair = one
    # ring bytes: ag r(n-1)/n with r=1024B n=4; ar 2r(n-1)/n with r=256B n=2
    assert cen["ring_bytes"]["all-gather"] == 1024 * 3 / 4
    assert cen["ring_bytes"]["all-reduce"] == 2 * 256 / 2
    assert cen["max_payload"]["all-gather"] == 1024
    assert len(cen["host_transfers"]) == 2         # outfeed + callback, not TopK
    assert cen["unknown_dtypes"] == []


@pytest.mark.parametrize("line,expect", [
    ("  o = f32[8]{0} outfeed(t), outfeed_config=\"\"", True),
    ("  s = f32[8]{0} send(t, tok), channel_id=1", True),
    ("  c = f32[4] custom-call(x), custom_call_target=\"TopK\"", False),
    ("  c = f32[4] custom-call(x), custom_call_target=\"xla_python_cpu_callback\"", True),
    ("  ROOT t = (f32[4]) tuple(a)", False),
    ("no-equals-here", False),
])
def test_is_host_transfer_line(line, expect):
    assert K.is_host_transfer_line(line) is expect


# --- the ANALYSIS_census.json CI gate (check_regression) ------------------

def _census_errors(fresh, base, tol=1.5):
    return CR.check_census(fresh, base, tol)


def test_gate_self_round_trip(baseline):
    assert _census_errors(copy.deepcopy(baseline), baseline) == []


def test_gate_flags_count_change(baseline):
    fresh = copy.deepcopy(baseline)
    e = fresh["census_points"][0]
    e["counts"]["all-gather"] += 1
    errs = _census_errors(fresh, baseline)
    assert len(errs) == 1
    assert "all-gather count" in errs[0] and e["spec"] in errs[0]


def test_gate_flags_matrix_dropout(baseline):
    fresh = copy.deepcopy(baseline)
    gone = fresh["census_points"].pop(2)
    errs = _census_errors(fresh, baseline)
    assert len(errs) == 1
    assert "matrix dropout" in errs[0] and gone["spec"] in errs[0]


def test_gate_flags_fresh_violations_and_contract_drift(baseline):
    fresh = copy.deepcopy(baseline)
    e = fresh["census_points"][1]
    e["violations"] = ["epso-no-full-param-gather: injected"]
    e["contracts"] = [c for c in e["contracts"]
                      if c != "no-gspmd-ragged-dot"]
    errs = _census_errors(fresh, baseline)
    assert any("contract violation" in m for m in errs)
    assert any("contract set changed" in m for m in errs)


def test_gate_ring_bytes_tolerance(baseline):
    fresh = copy.deepcopy(baseline)
    e = fresh["census_points"][0]
    kind = next(k for k, v in e["ring_bytes"].items()
                if k != "total" and v > 0)
    e["ring_bytes"][kind] *= 1.4                   # inside 1.5x: fine
    assert _census_errors(fresh, baseline) == []
    e["ring_bytes"][kind] *= 2.0                   # now ~2.8x: flagged
    errs = _census_errors(fresh, baseline)
    assert any("ring bytes" in m and kind in m for m in errs)


def test_check_pair_detects_census_kind(baseline):
    class A:
        census_tol = 1.5
    kind, errs = CR.check_pair(copy.deepcopy(baseline), baseline, A)
    assert kind == "census" and errs == []


# --- end-to-end: trace one real plan under 8 forced devices ---------------

@pytest.mark.slow
def test_collect_plan_census_end_to_end(mesh8):
    """Lower+compile the EPSO ring plan on 8 forced host devices and run
    the full census: the declared contracts hold, the all-gather payloads
    stay far below the full-param bytes, and the analytic cost model
    agrees within tolerance."""
    out = mesh8("""
import json
from repro.analysis import census as C
e = C.collect_plan_census("dp=2,ep=2,tp=2,opt=epso,overlap=ring")
print(json.dumps({
    "violations": e["violations"],
    "contracts": e["contracts"],
    "ag_max": e["max_payload"].get("all-gather", 0),
    "fp": e["full_param_bytes"],
    "total": e["ring_bytes"]["total"],
    "analytic": e["analytic_total"],
}))
""")
    got = json.loads(out.strip().splitlines()[-1])
    assert got["violations"] == []
    assert "epso-no-full-param-gather" in got["contracts"]
    assert 0 < got["ag_max"] < got["fp"]
    assert got["total"] > 0 and got["analytic"] > 0
