"""Unit tests for launch/roofline.py: the HLO collective parser against
real HLO text fixtures (iota replica groups, async -start/-done pairs,
tuple result shapes, unknown dtypes), plus the HardwareSpec registry and
the analytic VMEM working-set model the autotuner/guardrail share."""
import math

import pytest

from repro.launch import roofline as RL

# --- HLO fixtures: the instruction formats XLA actually emits -------------

HLO_BASIC = """
HloModule m
  %p = f32[1024,256]{1,0} parameter(0)
  %ag = f32[1024,256]{1,0} all-gather(%p), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = bf16[512]{0} all-reduce(%p), replica_groups={{0,1}}, to_apply=%add
  %rs = f32[128]{0} reduce-scatter(%p), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %cp = f32[64]{0} collective-permute(%p), source_target_pairs={{0,1},{1,0}}
"""

HLO_IOTA = """
  %ag = bf16[256]{0} all-gather(%p), replica_groups=[2,4]<=[8], dimensions={0}
"""

HLO_ASYNC = """
  %ars = f32[1000]{0} all-reduce-start(%p), replica_groups={{0,1}}, to_apply=%add
  %ard = f32[1000]{0} all-reduce-done(%ars)
"""

HLO_TUPLE = """
  %ags = (f32[64]{0}, f32[256]{0}) all-gather-start(%p), replica_groups={{0,1,2,3}}, dimensions={0}
  %agd = f32[256]{0} all-gather-done(%ags)
"""

HLO_UNKNOWN_DTYPE = """
  %ag = f8e3m4[100]{0} all-gather(%p), replica_groups={{0,1,2,3}}, dimensions={0}
"""


def test_collective_bytes_ring_model():
    c = RL.collective_bytes(HLO_BASIC)
    # all-gather: 1024*256*4 bytes, n=4 -> *(3/4)
    assert c["all-gather"] == pytest.approx(1024 * 256 * 4 * 3 / 4)
    # all-reduce: 512*2 bytes, n=2 -> 2*(1/2)x
    assert c["all-reduce"] == pytest.approx(2 * 512 * 2 * 1 / 2)
    # reduce-scatter: 128*4 bytes, n=4 -> *(n-1)
    assert c["reduce-scatter"] == pytest.approx(128 * 4 * 3)
    # collective-permute: result bytes as-is
    assert c["collective-permute"] == pytest.approx(64 * 4)
    assert c["total"] == pytest.approx(
        sum(v for k, v in c.items() if k not in ("total", "unknown_dtypes")))
    assert c["unknown_dtypes"] == []


def test_group_size_iota_format():
    # replica_groups=[2,4]<=[8]: 2 groups of size 4
    c = RL.collective_bytes(HLO_IOTA)
    assert c["all-gather"] == pytest.approx(256 * 2 * 3 / 4)


def test_async_pair_counted_once():
    c = RL.collective_bytes(HLO_ASYNC)
    # the -start carries the cost; the -done must not double it
    assert c["all-reduce"] == pytest.approx(2 * 1000 * 4 * 1 / 2)


def test_tuple_shape_sums_components():
    c = RL.collective_bytes(HLO_TUPLE)
    # (f32[64], f32[256]) start tuple: both components counted, n=4;
    # the f32[256] -done line is skipped
    assert c["all-gather"] == pytest.approx((64 + 256) * 4 * 3 / 4)


def test_unknown_dtype_counted_not_dropped():
    """Satellite fix: an unrecognized dtype used to zero out the
    instruction's bytes silently; now it costs 4 B/elt and is surfaced."""
    c = RL.collective_bytes(HLO_UNKNOWN_DTYPE)
    assert c["unknown_dtypes"] == ["f8e3m4"]
    assert c["all-gather"] == pytest.approx(100 * 4 * 3 / 4)
    assert c["total"] > 0


def test_shape_bytes_narrow_dtypes():
    assert RL._shape_bytes("f8e4m3fn[16]") == 16
    assert RL._shape_bytes("s4[8]") == 8        # byte-padded storage
    assert RL._shape_bytes("pred[10]") == 10


def test_group_size_defaults_to_two():
    assert RL._group_size("all-reduce(%x), to_apply=%add") == 2
    assert RL._group_size(
        "all-reduce(%x), replica_groups={{0,1,2}}, to_apply=%add") == 3


# --- HardwareSpec registry + working-set model ----------------------------

def test_hardware_registry():
    v5e = RL.get_hardware("tpu-v5e")
    assert v5e.peak_flops == RL.PEAK_FLOPS
    assert v5e.vmem_bytes == 16 * 2**20
    pvc = RL.get_hardware("pvc-tile")
    # per-tile: half of the Max 1550's 832 TF/s bf16
    assert pvc.peak_flops == pytest.approx(416e12)
    assert RL.get_hardware("sim-cpu").name == "sim-cpu"
    with pytest.raises(ValueError, match="unknown hardware"):
        RL.get_hardware("tpu-v9000")


def test_roofline_time_is_max_of_terms():
    hw = RL.HardwareSpec("t", peak_flops=100.0, hbm_bw=10.0, link_bw=1.0,
                         vmem_bytes=1)
    assert hw.roofline_time(1000.0, 1.0) == pytest.approx(10.0)   # compute
    assert hw.roofline_time(1.0, 1000.0) == pytest.approx(100.0)  # memory


def test_gmm_working_set_bytes():
    # inputs double-buffered at 2 B, f32 accumulator single-buffered
    ws = RL.gmm_working_set_bytes(128, 512, 512)
    assert ws == (128 * 512 + 512 * 512) * 2 * 2 + 128 * 512 * 4
    assert ws < RL.get_hardware("tpu-v5e").vmem_bytes  # default plan fits
    single = RL.gmm_working_set_bytes(128, 512, 512, double_buffer=False)
    assert single == (128 * 512 + 512 * 512) * 2 + 128 * 512 * 4
    assert not math.isnan(ws)


# --- walk_collectives: the reusable HLO pass the census shares ------------

# async collective-permute start/done pair + a tuple-sharded all-gather
# output: the exact formats the refactor must keep counting once each
HLO_ASYNC_CP = """
  %cps = bf16[32,128]{1,0} collective-permute-start(%p), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  %cpd = bf16[32,128]{1,0} collective-permute-done(%cps)
  %ags = (f32[64]{0}, f32[256]{0}) all-gather-start(%p), replica_groups={{0,1,2,3}}, dimensions={0}
  %agd = f32[256]{0} all-gather-done(%ags)
  %ag2 = f32[16]{0} all-gather(%q), replica_groups={{0,1}}, dimensions={0}
"""


def test_walk_collectives_async_cp_and_tuple():
    instrs = list(RL.walk_collectives(HLO_ASYNC_CP))
    kinds = [i.kind for i in instrs]
    # -done halves skipped: one cp, one (tuple) ag-start, one sync ag
    assert kinds == ["collective-permute", "all-gather", "all-gather"]
    cp, ag_t, ag_s = instrs
    assert cp.is_async and cp.result_bytes == 32 * 128 * 2
    assert cp.ring_bytes == pytest.approx(32 * 128 * 2)   # permute: as-is
    assert ag_t.is_async and ag_t.group_size == 4
    assert ag_t.result_bytes == (64 + 256) * 4            # tuple summed
    assert ag_t.ring_bytes == pytest.approx((64 + 256) * 4 * 3 / 4)
    assert not ag_s.is_async and ag_s.group_size == 2


@pytest.mark.parametrize("hlo", [HLO_BASIC, HLO_IOTA, HLO_ASYNC,
                                 HLO_TUPLE, HLO_UNKNOWN_DTYPE,
                                 HLO_ASYNC_CP])
def test_walker_totals_match_collective_bytes_bitwise(hlo):
    """Satellite 3: the census built on walk_collectives must agree with
    the roofline's collective_bytes bit-for-bit on every fixture."""
    from repro.analysis.census import hlo_census
    cb = RL.collective_bytes(hlo)
    census = hlo_census(hlo)
    per_kind_sum = 0.0
    for kind in RL.COLLECTIVE_KINDS:
        assert census["ring_bytes"][kind] == cb[kind], kind
        per_kind_sum += census["ring_bytes"][kind]
    assert census["ring_bytes"]["total"] == cb["total"]
    assert census["unknown_dtypes"] == cb["unknown_dtypes"]
    # counts are consistent with bytes: zero bytes iff zero instructions
    for kind in RL.COLLECTIVE_KINDS:
        assert (census["counts"][kind] == 0) == (cb[kind] == 0.0), kind


def test_ring_model_bytes_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown collective kind"):
        RL.ring_model_bytes("all-bogus", 1.0, 2)
