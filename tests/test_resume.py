"""Deterministic resume (paper §4 restart hygiene): N steps -> checkpoint ->
restore -> N more steps must be *bit-identical* to 2N uninterrupted steps —
same data order (the loader is a pure function of the global step), same
jitted executable, same SO/EPSO state placement after reshard-on-restore.

Runs through the real launcher (`repro.launch.train.run`) on a forced
8-CPU-device (4,2) mesh for both `opt_shard=none` and `opt_shard=epso`.
"""
import pytest

pytestmark = [pytest.mark.distributed, pytest.mark.slow]


def test_resume_bit_identical_none_and_epso(mesh8, tmp_path):
    out = mesh8(f"""
        import json, os
        import numpy as np
        from repro.launch.train import run

        base = {str(tmp_path)!r}
        KW = dict(batch=8, seq=32, d_model=64, ckpt_interval=5,
                  mesh="4,2", log_every=100)

        def newest_state(out_dir, want_step):
            root = os.path.join(out_dir, "ckpt")
            for slot in ("ckpt-1", "ckpt-2"):
                man = os.path.join(root, slot, "MANIFEST.json")
                if not os.path.exists(man):
                    continue
                with open(man) as f:
                    m = json.load(f)
                if m.get("valid") and int(m["step"]) == want_step:
                    return dict(np.load(os.path.join(root, slot,
                                                     "state.npz")))
            raise AssertionError(f"no valid checkpoint @ {{want_step}} "
                                 f"in {{out_dir}}")

        for mode in ("none", "epso"):
            d = f"{{base}}/{{mode}}"
            straight = run("mula-7b-a1b", steps=11, out=f"{{d}}/straight",
                           opt_shard=mode, **KW)
            run("mula-7b-a1b", steps=6, out=f"{{d}}/resumed",
                opt_shard=mode, **KW)                    # ckpt at step 5
            resumed = run("mula-7b-a1b", steps=11, out=f"{{d}}/resumed",
                          opt_shard=mode, **KW)          # restores, 6..10
            # the resumed invocation starts exactly after the checkpoint
            assert [h["step"] for h in resumed] == list(range(6, 11)), mode
            # loss history over the overlap is bit-identical
            la = [h["loss"] for h in straight if h["step"] >= 6]
            lb = [h["loss"] for h in resumed]
            assert la == lb, (mode, la, lb)
            # full state (params + master/m/v + step) at step 10 bit-identical
            sa = newest_state(f"{{d}}/straight", 10)
            sb = newest_state(f"{{d}}/resumed", 10)
            assert sorted(sa) == sorted(sb)
            for k in sa:
                assert sa[k].dtype == sb[k].dtype, (mode, k)
                assert np.array_equal(sa[k], sb[k]), (mode, k)
            print(f"{{mode}}: OK")
        print("ALL-OK")
    """, timeout=1800)
    assert "ALL-OK" in out


def test_resume_bit_identical_pp_epso(mesh8, tmp_path):
    """PP x EPSO composition (the paper's Mula-100B/220B layout, reduced):
    on a (data=2, pp=2, model=2) mesh with the jitted 1f1b schedule and
    EP-aware sharded optimizer, a run that loses a node mid-flight (hard
    failure -> buffer swap -> restore -> replay) ends bit-identical to an
    uninterrupted run — loss history and the full checkpointed state."""
    out = mesh8(f"""
        import json, os
        import numpy as np
        from repro.launch.train import run

        base = {str(tmp_path)!r}
        KW = dict(batch=8, seq=32, d_model=64, ckpt_interval=5,
                  mesh="2,2,2", opt_shard="epso", pp_schedule="1f1b",
                  log_every=100)

        straight = run("mula-7b-a1b", steps=11, out=f"{{base}}/straight",
                       **KW)
        injected = run("mula-7b-a1b", steps=11, out=f"{{base}}/injected",
                       inject_hard_at=7, **KW)
        assert injected.relaunches == 1, injected.relaunches
        la = [h["loss"] for h in straight]
        lb = [h["loss"] for h in injected]
        assert la == lb, (la, lb)

        def newest(d, want):
            for slot in ("ckpt-1", "ckpt-2"):
                man = os.path.join(d, "ckpt", slot, "MANIFEST.json")
                if os.path.exists(man):
                    with open(man) as f:
                        m = json.load(f)
                    if m.get("valid") and int(m["step"]) == want:
                        return dict(np.load(os.path.join(d, "ckpt", slot,
                                                         "state.npz")))
            raise AssertionError(f"no valid ckpt @ {{want}} in {{d}}")

        sa = newest(f"{{base}}/straight", 10)
        sb = newest(f"{{base}}/injected", 10)
        assert sorted(sa) == sorted(sb)
        for k in sa:
            assert sa[k].dtype == sb[k].dtype, k
            assert np.array_equal(sa[k], sb[k]), k
        print("PP-EPSO-RESUME-OK")
    """, timeout=1800)
    assert "PP-EPSO-RESUME-OK" in out
