"""Pallas flash-attention kernel vs dense oracle: shape/dtype/mask sweeps +
equality with the model's blockwise-JAX attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _oracle(q, k, v, causal, win):
    B, Sq, nh, hd = q.shape
    rep = nh // k.shape[2]
    kf, vf = jnp.repeat(k, rep, 2), jnp.repeat(v, rep, 2)
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(B * nh, a.shape[1], hd)
    out = ref.flash_attention_ref(fold(q), fold(kf), fold(vf), causal=causal,
                                  window=win)
    return out.reshape(B, nh, Sq, hd).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("B,S,nh,nkv,hd", [(2, 64, 4, 4, 32),
                                           (1, 48, 4, 2, 16),
                                           (2, 96, 8, 1, 64)])
@pytest.mark.parametrize("causal,win", [(True, 0), (True, 16), (False, 0)])
def test_flash_attention_sweep(B, S, nh, nkv, hd, causal, win):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, nh, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, nkv, hd))
    out = ops.flash_attention(q, k, v, causal=causal, window=win,
                              q_block=16, kv_block=16)
    np.testing.assert_allclose(out, _oracle(q, k, v, causal, win), atol=2e-5)


def test_flash_attention_bf16():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 4, 32), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 4, 32), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 4, 32), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, q_block=32, kv_block=32)
    expect = _oracle(q, k, v, True, 0)
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(expect, np.float32), atol=3e-2)


def test_flash_matches_model_blockwise_attention():
    """The kernel and the pure-JAX blockwise attention (layers.py) compute
    the same function (that path is the training/bwd implementation)."""
    from repro.models.layers import _blockwise_attention
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 40, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 40, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 40, 2, 16))
    a = ops.flash_attention(q, k, v, causal=True, window=8,
                            q_block=16, kv_block=16)
    b = _blockwise_attention(q, k, v, causal=True, window=8,
                             q_block=16, kv_block=16)
    np.testing.assert_allclose(a, b, atol=2e-5)
