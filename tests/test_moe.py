"""FastSparseMoE: implementation equivalence, dispatch properties, FUR."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import moe as M
from repro.core.router import route


def make_cfg(E=8, K=2, d=32, f=16, cf=None, **kw):
    return ModelConfig(
        name="t", arch_type="moe", num_layers=1, d_model=d, num_heads=2,
        num_kv_heads=2, d_ff=0, vocab_size=64,
        moe=MoEConfig(num_experts=E, experts_per_token=K, d_ff_expert=f,
                      capacity_factor=cf if cf is not None else E / K, **kw))


@pytest.fixture
def setup():
    cfg = make_cfg()
    p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    return cfg, p, x


def test_impl_equivalence_dropless(setup):
    """naive == dense_capacity(xla) == ragged == pallas in the dropless
    regime, forward and all gradients."""
    cfg, p, x = setup
    from repro.kernels import ops
    small = dataclasses.replace(ops.current_kernel_plan(), tile_m=8)
    with ops.use_kernel_plan(small):   # scoped: no cross-test state leak
        ref_out, _ = M.moe_naive(p, x, cfg.moe)
        ref_g = jax.grad(
            lambda p: (M.moe_naive(p, x, cfg.moe)[0] ** 2).sum())(p)
        for be in ("xla", "ragged", "pallas"):
            out, _ = M.moe_dense_capacity(p, x, cfg.moe, backend=be)
            np.testing.assert_allclose(out, ref_out, atol=1e-4, err_msg=be)
            g = jax.grad(lambda p, be=be: (M.moe_dense_capacity(
                p, x, cfg.moe, backend=be)[0] ** 2).sum())(p)
            for k in ("router", "gate", "up", "down"):
                np.testing.assert_allclose(g[k], ref_g[k], atol=1e-3,
                                           err_msg=f"{be}/{k}")


def test_capacity_drops_counted():
    cfg = make_cfg(cf=0.5)     # half capacity -> guaranteed drops
    p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 32))
    r = route(x, p["router"], num_experts=8, top_k=2)
    rows = M.pool_size(128, 2, 8, 8, 0.5)
    plan = M.make_dispatch_plan(r.indices, num_experts=8, pool_rows=rows)
    assert int(plan.drops) > 0
    assert int(plan.valid.sum()) + int(plan.drops) == 128 * 2


def test_shared_experts():
    cfg = make_cfg(num_shared_experts=2)
    p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
    out_with, _ = M.moe_dense_capacity(p, x, cfg.moe)
    p2 = dict(p)
    p2["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    out_without, _ = M.moe_dense_capacity(p2, x, cfg.moe)
    assert not np.allclose(out_with, out_without)


def test_fur_uniform_routing():
    """FUR (paper §2.3): every expert receives exactly the same count."""
    cfg = make_cfg(forced_uniform_routing=True)
    p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    r = route(x, p["router"], num_experts=8, top_k=2, forced_uniform=True)
    counts = np.bincount(np.array(r.indices).reshape(-1), minlength=8)
    assert counts.min() == counts.max() == 64 * 2 // 8
    # FUR is dropless at cf = 1
    rows = M.pool_size(64, 2, 8, 8, 1.0)
    plan = M.make_dispatch_plan(r.indices, num_experts=8, pool_rows=rows)
    assert int(plan.drops) == 0


def test_router_aux_losses_finite_and_ordered():
    cfg = make_cfg()
    p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 32))
    r = route(x, p["router"], num_experts=8, top_k=2)
    # aux >= 1 (equality iff perfectly balanced); z finite
    assert float(r.aux_loss) >= 0.99
    assert np.isfinite(float(r.z_loss))
    rf = route(x, p["router"], num_experts=8, top_k=2, forced_uniform=True)
    # FUR is perfectly balanced -> aux at its minimum given probs
    assert float(rf.aux_loss) <= float(r.aux_loss) + 0.05


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 16), st.integers(1, 4), st.integers(8, 128),
       st.integers(0, 3))
def test_dispatch_plan_properties(E, K, T, seed):
    """Hypothesis invariants (paper Stages 2+3):
       - counts sum to the number of local routing pairs
       - valid slots are unique and within the pool
       - every valid (t,k) lands in its expert's [offset, offset+size) range
    """
    K = min(K, E)
    idx = jax.random.randint(jax.random.PRNGKey(seed), (T, K), 0, E)
    rows = M.pool_size(T, K, E, E, float(E))   # dropless
    plan = M.make_dispatch_plan(idx, num_experts=E, pool_rows=rows)
    counts = np.array(plan.counts)
    assert counts.sum() == T * K
    assert int(plan.drops) == 0
    slot = np.array(plan.slot)
    valid = np.array(plan.valid)
    vs = slot[valid]
    assert len(set(vs.tolist())) == len(vs)          # permutation into pool
    assert vs.max(initial=-1) < rows
    # group membership: slot within its expert's range
    gs = np.array(plan.group_sizes)
    offsets = np.concatenate([[0], np.cumsum(gs)])
    flat_e = np.array(idx).reshape(-1)
    for i in np.nonzero(valid)[0]:
        e = flat_e[i]
        assert offsets[e] <= slot[i] < offsets[e + 1]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5))
def test_combine_linearity_property(seed):
    """Stage 5 is linear in both inputs."""
    from repro.kernels import ref
    r1 = jax.random.normal(jax.random.PRNGKey(seed), (16, 2, 8))
    r2 = jax.random.normal(jax.random.PRNGKey(seed + 99), (16, 2, 8))
    w = jax.random.normal(jax.random.PRNGKey(seed + 7), (16, 2))
    lhs = ref.combine_ref(r1 + 2.0 * r2, w)
    rhs = ref.combine_ref(r1, w) + 2.0 * ref.combine_ref(r2, w)
    np.testing.assert_allclose(lhs, rhs, atol=1e-5)


def test_sparse_moe_block_entrypoint(setup):
    cfg, p, x = setup
    out, aux, z, stats = M.sparse_moe_block(p, x.reshape(4, 16, 32), cfg)
    assert out.shape == (4, 16, 32)
    assert np.isfinite(float(aux)) and np.isfinite(float(z))
    # telemetry: every (token, expert) routing is counted
    K = cfg.moe.experts_per_token
    assert stats.counts.shape == (cfg.moe.num_experts,)
    assert int(stats.counts.sum()) + int(stats.drops) == 4 * 16 * K
