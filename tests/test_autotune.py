"""Tests for the measured kernel autotuner (kernels/autotune.py) and its
plumbing: shape buckets, tuning-table persistence and lookup fallback,
VMEM candidate pruning, the KernelPlan guardrail + ``tiles='auto'``
resolution, the ``tiles=`` ParallelPlan token, and end-to-end bit-identity
of auto-vs-explicit tiles through a real train step."""
import json
import warnings

import numpy as np
import pytest

from repro.kernels import autotune as AT
from repro.parallel.plan import KernelPlan, ParallelPlan, use_kernel_plan


@pytest.fixture(autouse=True)
def _isolated_table():
    """Never let these tests see (or mutate) the committed table."""
    prev = AT._ACTIVE[0]
    AT.set_active_table(None)
    yield
    AT._ACTIVE[0] = prev


def _entry(kernel="gmm", backend="pallas", dims=None, tiles=(64, 256, 512)):
    dims = dims or {"g": 2, "m": 256, "k": 512, "n": 1792}
    return {"kernel": kernel, "backend": backend,
            "bucket": AT.bucket_dims(kernel, dims), "shape": dict(dims),
            "tiles": list(tiles), "time_ms": 1.0,
            "default_tiles": [128, 512, 512], "default_time_ms": 2.0,
            "n_iters": 3, "hw": "tpu-v5e"}


# --- buckets --------------------------------------------------------------

def test_pow2_bucket():
    assert [AT.pow2_bucket(n) for n in (1, 2, 3, 129, 1792)] == \
        [1, 2, 4, 256, 2048]


def test_bucket_key_order_and_rounding():
    key = AT.bucket_key("gmm", {"g": 2, "m": 200, "k": 512, "n": 1792})
    assert key == "g2_k512_m256_n2048"


# --- tuning table ---------------------------------------------------------

def test_table_add_replaces_same_bucket():
    t = AT.TuningTable()
    t.add(_entry(tiles=(64, 256, 512)))
    t.add(_entry(tiles=(32, 512, 512)))
    assert len(t.entries) == 1
    assert t.entries[0]["tiles"] == [32, 512, 512]


def test_table_lookup_exact_and_nearest_m():
    t = AT.TuningTable()
    t.add(_entry(dims={"g": 2, "m": 256, "k": 512, "n": 1792}))
    # exact bucket (m=200 rounds into the m256 bucket)
    assert t.lookup("gmm", "pallas",
                    {"g": 2, "m": 200, "k": 512, "n": 1792}) == (64, 256, 512)
    # m miss with all other dims equal: nearest-m fallback
    assert t.lookup("gmm", "pallas",
                    {"g": 2, "m": 4096, "k": 512, "n": 1792}) == (64, 256, 512)
    # non-dynamic dim miss: full miss
    assert t.lookup("gmm", "pallas",
                    {"g": 2, "m": 256, "k": 99, "n": 1792}) is None
    # backend mismatch: miss
    assert t.lookup("gmm", "xla",
                    {"g": 2, "m": 256, "k": 512, "n": 1792}) is None


def test_table_save_load_round_trip(tmp_path):
    t = AT.TuningTable(hw="pvc-tile")
    t.add(_entry())
    path = t.save(str(tmp_path / "table.json"))
    back = AT.TuningTable.load(path)
    assert back is not None
    assert back.hw == "pvc-tile"
    assert back.lookup("gmm", "pallas",
                       {"g": 2, "m": 256, "k": 512, "n": 1792}) == \
        (64, 256, 512)


def test_table_load_version_mismatch_returns_none(tmp_path):
    p = tmp_path / "stale.json"
    p.write_text(json.dumps({"version": 0, "entries": []}))
    with pytest.warns(UserWarning, match="version"):
        assert AT.TuningTable.load(str(p)) is None
    q = tmp_path / "garbage.json"
    q.write_text("not json{")
    with pytest.warns(UserWarning, match="unreadable"):
        assert AT.TuningTable.load(str(q)) is None


# --- candidates + pruning -------------------------------------------------

def test_gmm_candidates_respect_alignment_and_include_default():
    dims = {"g": 2, "m": 256, "k": 512, "n": 1792}
    cands = AT.gmm_candidates(dims)
    assert (128, 512, 512) in cands
    rows = dims["m"] // dims["g"]
    assert all(rows % tm == 0 for tm, _, _ in cands)


def test_prune_candidates_drops_oversized():
    huge = (256, 2048, 2048)     # ~21 MiB working set
    kept = AT.prune_candidates("gmm", [huge, (128, 512, 512)], hw="tpu-v5e")
    assert kept == [(128, 512, 512)]
    # the PVC tile's 204 MiB budget keeps both
    assert len(AT.prune_candidates("gmm", [huge, (128, 512, 512)],
                                   hw="pvc-tile")) == 2


# --- active table + observed lookups --------------------------------------

def test_lookup_tiles_observed_hit_and_miss():
    t = AT.TuningTable()
    t.add(_entry(dims={"g": 2, "m": 64, "k": 16, "n": 32}, tiles=(16, 16, 32)))
    with AT.use_tuning_table(t), AT.observe_lookups() as seen:
        hit = AT.lookup_tiles("gmm", "pallas",
                              {"g": 2, "m": 64, "k": 16, "n": 32})
        miss = AT.lookup_tiles("gmm", "pallas",
                               {"g": 2, "m": 64, "k": 999, "n": 32})
    assert hit == (16, 16, 32) and miss is None
    assert [r["tiles"] for r in seen] == [(16, 16, 32), None]
    assert seen[0]["bucket"] == "g2_k16_m64_n32"


def test_lookup_tiles_without_table_is_none():
    assert AT.lookup_tiles("gmm", "pallas",
                           {"g": 2, "m": 64, "k": 16, "n": 32}) is None


# --- KernelPlan: guardrail, tiles field, resolve_tiles --------------------

def test_kernel_plan_vmem_guardrail_warns():
    with pytest.warns(UserWarning, match="fast memory"):
        KernelPlan(tile_m=1024, tile_k=4096, tile_n=4096)


def test_kernel_plan_vmem_guardrail_strict_raises():
    with pytest.raises(ValueError, match="fast memory"):
        KernelPlan(tile_m=1024, tile_k=4096, tile_n=4096, strict=True)


def test_kernel_plan_default_tiles_fit_silently():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        KernelPlan()


def test_kernel_plan_tiles_field_validated():
    KernelPlan(tiles="auto")
    KernelPlan(tiles=None)
    with pytest.raises(ValueError, match="tiles"):
        KernelPlan(tiles="always")


def test_resolve_tiles_only_when_auto():
    t = AT.TuningTable()
    t.add(_entry(dims={"g": 2, "m": 64, "k": 16, "n": 32}, tiles=(16, 16, 32)))
    dims = {"g": 2, "m": 64, "k": 16, "n": 32}
    with AT.use_tuning_table(t):
        kp = KernelPlan(backend="pallas", tiles="auto")
        assert kp.resolve_tiles("gmm", dims) == (16, 16, 32)
        assert KernelPlan(backend="pallas").resolve_tiles("gmm", dims) is None


# --- ParallelPlan tiles= token --------------------------------------------

def test_plan_tiles_token_auto_round_trip():
    plan = ParallelPlan.parse("dp=2,ep=2,tp=2,tiles=auto")
    assert plan.kernel.tiles == "auto"
    assert "tiles=auto" in str(plan)
    assert ParallelPlan.parse(str(plan)) == plan


def test_plan_tiles_token_explicit_round_trip():
    plan = ParallelPlan.parse("dp=2,tiles=64x256x512")
    assert (plan.kernel.tile_m, plan.kernel.tile_k, plan.kernel.tile_n) == \
        (64, 256, 512)
    assert plan.kernel.tiles is None
    assert "tiles=64x256x512" in str(plan)
    assert ParallelPlan.parse(str(plan)) == plan


def test_plan_tiles_token_rejects_garbage():
    with pytest.raises(ValueError, match="tiles"):
        ParallelPlan.parse("dp=2,tiles=64x256")
    with pytest.raises(ValueError, match="tiles"):
        ParallelPlan.parse("dp=2,tiles=fast")


# --- ops integration: auto tiles through the gmm wrapper ------------------

def test_gmm_auto_tiles_applied_and_match_ref():
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    G, M, K, N = 2, 64, 16, 32
    t = AT.TuningTable()
    t.add(_entry(dims={"g": G, "m": M, "k": K, "n": N}, tiles=(16, 16, 32)))
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (G, K, N))
    gs = jnp.array([32, 32], jnp.int32)
    kp = KernelPlan(backend="pallas", tile_m=16, tiles="auto")
    with AT.use_tuning_table(t), use_kernel_plan(kp), \
            AT.observe_lookups() as seen:
        out = ops.gmm(x, w, gs)
    fwd = [r for r in seen if r["kernel"] == "gmm"]
    assert fwd and fwd[0]["tiles"] == (16, 16, 32)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.gmm_ref(x, w, gs)),
                               rtol=1e-4, atol=1e-4)


def test_gmm_auto_tile_m_clamped_to_alignment():
    """A table tile_m that does not divide the plan's tile_m (the dispatch
    padding quantum) must be ignored — applying it would violate the
    ``group_sizes % tile_m == 0`` kernel contract."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    G, M, K, N = 2, 64, 16, 32
    t = AT.TuningTable()
    t.add(_entry(dims={"g": G, "m": M, "k": K, "n": N}, tiles=(24, 16, 32)))
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (G, K, N))
    gs = jnp.array([32, 32], jnp.int32)
    kp = KernelPlan(backend="pallas", tile_m=16, tiles="auto")
    with AT.use_tuning_table(t), use_kernel_plan(kp):
        out = ops.gmm(x, w, gs)   # tm=24 dropped; tk/tn still applied
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.gmm_ref(x, w, gs)),
                               rtol=1e-4, atol=1e-4)


# --- end-to-end: bit-identical loss, auto vs explicit tiles ---------------

@pytest.mark.slow
def test_train_step_auto_tiles_bit_identical():
    """``tiles='auto'`` with a table whose entries equal the plan's explicit
    tiles must produce bit-identical losses to the explicit plan — the auto
    path changes where tile sizes come from, never the math. The table is
    built from an observed trace so every bucket the step consults (fwd +
    bwd gmm, tgmm, swiglu, combine) is covered."""
    import jax

    from repro.configs import TrainConfig, get_config, reduced
    from repro.train import init_state, make_train_step

    cfg = reduced(get_config("mula-7b-a1b"), layers=1, d_model=64)
    tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                     grad_reduce_dtype="float32", lr_peak=1e-3, lr_min=1e-4,
                     warmup_steps=2, total_steps=4, seq_len=16,
                     global_batch=2)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def run(tiles, table):
        plan = ParallelPlan(
            kernel=KernelPlan(backend="pallas", tiles=tiles)
        ).resolve(cfg, global_batch=2)
        state = init_state(jax.random.PRNGKey(0), cfg, tc, plan=plan)
        with AT.use_tuning_table(table), AT.observe_lookups() as seen:
            fn = make_train_step(cfg, None, tc, plan=plan)
            losses = []
            for _ in range(2):
                state, m = fn(state, batch)
                losses.append(float(m["loss"]))
        return losses, seen

    # explicit leg also discovers which (kernel, bucket) lookups the step
    # would make, so the auto leg's table can cover every one of them
    base_losses, _ = run(None, None)
    _, observed = run("auto", AT.TuningTable())     # empty table: all misses
    assert observed, "auto plan made no tile lookups — wiring broken"

    table = AT.TuningTable()
    kp = KernelPlan()
    for r in observed:
        if r["kernel"] == "gmm":
            tiles = (kp.tile_m, kp.tile_k, kp.tile_n)
        elif r["kernel"] == "tgmm":
            tiles = (kp.tile_m, min(512, r["dims"]["k"]),
                     min(512, r["dims"]["n"]))
        else:
            continue       # elementwise kernels: leave as fallback
        table.add({"kernel": r["kernel"], "backend": "pallas",
                   "bucket": AT.bucket_dims(r["kernel"], r["dims"]),
                   "shape": dict(r["dims"]), "tiles": list(tiles),
                   "time_ms": 1.0, "default_tiles": list(tiles),
                   "default_time_ms": 1.0, "n_iters": 1, "hw": "tpu-v5e"})

    auto_losses, seen = run("auto", table)
    hits = [r for r in seen if r["tiles"] is not None]
    assert hits, "auto leg hit no table entries"
    assert auto_losses == base_losses, (auto_losses, base_losses)


# --- autotune() itself (tiny shape so it stays fast) ----------------------

@pytest.mark.slow
def test_autotune_records_best_and_default():
    dims = {"g": 2, "m": 32, "k": 16, "n": 16}
    table = AT.autotune("gmm", [dims], candidates=[(16, 16, 16), (8, 16, 16)],
                        n_iters=2, validate=True)
    e = table.find("gmm", "pallas", dims)
    assert e is not None
    assert tuple(e["tiles"]) in ((16, 16, 16), (8, 16, 16))
    # default tile_m legalized to the per-group row count (16) so the
    # default timing is well-defined on this tiny shape
    assert e["default_tiles"] == [16, 512, 512]
    assert e["time_ms"] > 0 and e["default_time_ms"] > 0
    assert e["gflops"] == pytest.approx(2 * 32 * 16 * 16 / 1e9)


def test_autotune_unknown_kernel_raises():
    with pytest.raises(ValueError, match="measurement adapter"):
        AT.autotune("conv3d", [{"m": 8}])
