"""Config registry: every assigned arch present, Table 1 counts reproduced."""
import pytest

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES,
                           get_config, reduced)


def test_all_assigned_archs_registered():
    assert len(ASSIGNED_ARCHS) == 10
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        assert cfg.name == a
        assert cfg.citation


def test_input_shapes():
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


# paper Table 1 totals (billions) with tolerance
TABLE1 = {"mula-1b": (1.3, 1.3), "mula-7b-a1b": (6.9, 1.3),
          "mula-20b-a2b": (20, 2.4), "mula-100b-a7b": (100, 7.6),
          "mula-220b-a10b": (220, 10)}


@pytest.mark.parametrize("name,expect", TABLE1.items())
def test_mula_param_counts_match_table1(name, expect):
    cfg = get_config(name)
    total, active = expect
    assert abs(cfg.param_count() / 1e9 - total) / total < 0.08
    assert abs(cfg.active_param_count() / 1e9 - active) / active < 0.08


@pytest.mark.parametrize("name,total", [
    ("dbrx-132b", 132), ("mixtral-8x7b", 46.7), ("llama3-405b", 405)])
def test_public_param_counts(name, total):
    assert abs(get_config(name).param_count() / 1e9 - total) / total < 0.05


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_configs_small(arch):
    cfg = reduced(get_config(arch))
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


def test_long_500k_applicability():
    """DESIGN §6: sub-quadratic archs only."""
    runs = [a for a in ASSIGNED_ARCHS
            if get_config(a).supports_long_decode]
    assert set(runs) == {"zamba2-7b", "falcon-mamba-7b", "mixtral-8x7b",
                         "starcoder2-3b"}
