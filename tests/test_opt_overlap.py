"""Overlapped SO/EPSO optimizer update (repro/optim/overlap.py).

Three layers:

* bucket-planner properties on an AbstractMesh — exact leaf coverage, size
  cap, added-axes/bucket-axes consistency, deterministic schedule;
* the ``resolve_opt_overlap`` request matrix (auto defaults, explicit
  impls, error cases);
* mesh8 goldens: overlapped (ring and xla) EPSO matches the eager update
  to ~1 ulp over 10 steps, SO composes with the overlap too, and the
  overlap composes with the shard_map pipeline executor on the
  (data=2, pp=2, model=2) mesh.
"""
import jax
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import AbstractMesh, AxisType

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.optim.epso import plan_update_buckets, update_axis_order
from repro.optim.overlap import resolve_opt_overlap
from repro.parallel.sharding import make_rules


def _mesh(shape=(4, 2), axes=("data", "model")):
    return AbstractMesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


@pytest.fixture(scope="module")
def plan_setup():
    cfg = reduced(get_config("mula-7b-a1b"), d_model=64)
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    mesh = _mesh()
    rules = make_rules(cfg, mesh, kind="train", global_batch=8)
    return cfg, shapes, mesh, rules


# ---------------------------------------------------------------------------
# bucket planner
# ---------------------------------------------------------------------------

def test_plan_covers_every_leaf_exactly_once(plan_setup):
    _, shapes, _, rules = plan_setup
    for mode in ("none", "so", "epso"):
        plan = plan_update_buckets(shapes, rules, mode)
        idxs = [lf.index for b in plan.buckets for lf in b.leaves]
        assert sorted(idxs) == list(range(plan.n_leaves))
        assert plan.n_leaves == len(jax.tree.leaves(shapes))
        assert plan.mode == mode


def test_plan_added_axes_match_bucket_axes(plan_setup):
    """Every leaf's extra axes are exactly its bucket's gather axes (else
    the fused gather would reassemble the wrong tiling), and the plan's
    union covers all buckets."""
    _, shapes, mesh, rules = plan_setup
    order = update_axis_order(mesh)
    plan = plan_update_buckets(shapes, rules, "epso")
    for b in plan.buckets:
        assert tuple(a for a in order if a in b.axes) == b.axes
        for lf in b.leaves:
            leaf_axes = {a for _, axes in lf.added for a in axes}
            assert leaf_axes == set(b.axes), (lf.path, b.axes)
            # psum axes cover the gather axes (state spec includes them)
            assert set(b.axes) <= set(lf.psum_axes), lf
    union = {a for b in plan.buckets for a in b.axes}
    assert set(plan.axes) == union


def test_plan_none_mode_is_all_local(plan_setup):
    """mode='none' state specs equal the param specs: every bucket is a
    local-only axes=() bucket — the overlap degenerates to no collectives."""
    _, shapes, _, rules = plan_setup
    plan = plan_update_buckets(shapes, rules, "none")
    assert all(b.axes == () for b in plan.buckets)
    assert plan.axes == ()


def test_plan_deterministic_and_ordered(plan_setup):
    _, shapes, _, rules = plan_setup
    p1 = plan_update_buckets(shapes, rules, "epso")
    p2 = plan_update_buckets(shapes, rules, "epso")
    assert p1 == p2
    firsts = [b.leaves[0].index for b in p1.buckets]
    assert firsts == sorted(firsts)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([1, 256, 4096, 65536, 1 << 20, 1 << 30]))
def test_plan_respects_cap(cap_bytes):
    """Under any cap, a multi-leaf bucket never exceeds it; a leaf larger
    than the cap sits alone in its bucket."""
    cfg = reduced(get_config("mula-7b-a1b"), d_model=64)
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    rules = make_rules(cfg, _mesh(), kind="train", global_batch=8)
    plan = plan_update_buckets(shapes, rules, "epso",
                               max_bucket_bytes=cap_bytes)
    max_elems = max(cap_bytes // 4, 1)
    flat = jax.tree.leaves(shapes)
    for b in plan.buckets:
        total = sum(flat[lf.index].size for lf in b.leaves)
        assert total == b.elems
        if len(b.leaves) > 1:
            assert total <= max_elems, (cap_bytes, total)
    idxs = sorted(lf.index for b in plan.buckets for lf in b.leaves)
    assert idxs == list(range(plan.n_leaves))


def test_plan_small_cap_isolates_large_leaves(plan_setup):
    """cap=1 byte forces one leaf per sharded bucket."""
    _, shapes, _, rules = plan_setup
    plan = plan_update_buckets(shapes, rules, "epso", max_bucket_bytes=1)
    for b in plan.buckets:
        if b.axes:
            assert len(b.leaves) == 1, b


# ---------------------------------------------------------------------------
# resolve_opt_overlap matrix
# ---------------------------------------------------------------------------

def test_resolve_matrix():
    mesh = _mesh()
    # auto (None or 'auto'): overlap only the mode that regressed
    assert resolve_opt_overlap(None, "epso", mesh) == "ring"
    assert resolve_opt_overlap("auto", "epso", mesh) == "ring"
    assert resolve_opt_overlap(None, "so", mesh) == "off"
    assert resolve_opt_overlap(None, "none", mesh) == "off"
    assert resolve_opt_overlap(None, "epso", None) == "off"
    # explicit off always wins
    assert resolve_opt_overlap("off", "epso", mesh) == "off"
    assert resolve_opt_overlap("off", "none", None) == "off"
    # explicit impls for the sharded modes
    assert resolve_opt_overlap("ring", "so", mesh) == "ring"
    assert resolve_opt_overlap("xla", "epso", mesh) == "xla"


def test_resolve_errors():
    mesh = _mesh()
    with pytest.raises(ValueError, match="opt_shard"):
        resolve_opt_overlap("ring", "none", mesh)
    with pytest.raises(ValueError, match="mesh"):
        resolve_opt_overlap("ring", "epso", None)
    with pytest.raises(ValueError, match="must be one of"):
        resolve_opt_overlap("bogus", "epso", mesh)
    # a mesh with no update axes can't host the gather
    pp_only = AbstractMesh((2,), ("pp",), axis_types=(AxisType.Auto,))
    assert resolve_opt_overlap(None, "epso", pp_only) == "off"
    with pytest.raises(ValueError, match="update axes"):
        resolve_opt_overlap("xla", "epso", pp_only)


# ---------------------------------------------------------------------------
# mesh8 goldens
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.slow
def test_overlap_golden_parity_mesh8(mesh8):
    """Overlapped updates (ring and xla) match the eager path to ~1 ulp
    over 10 steps on the (4,2) mesh, for both epso and so.

    The only numerical difference is the grad-norm's reduction order
    (shard-wise partial sums), so losses agree to float32 roundoff and
    final params to ~1e-6 absolute (measured drift ~1e-7)."""
    out = mesh8("""
        import jax, numpy as np
        from repro.configs import ParallelConfig, TrainConfig, get_config, reduced
        from repro.parallel.plan import ParallelPlan
        from repro.train import init_state, make_train_step

        cfg = reduced(get_config("mula-7b-a1b"), d_model=64)
        tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                         grad_reduce_dtype="float32", lr_peak=1e-3,
                         lr_min=1e-4, warmup_steps=2, total_steps=10,
                         seq_len=32, global_batch=8)
        batches = []
        for s in range(10):
            t = jax.random.randint(jax.random.PRNGKey(100 + s), (8, 33), 0,
                                   cfg.vocab_size)
            batches.append({"tokens": t[:, :-1], "labels": t[:, 1:]})

        def run(mode, overlap):
            plan = ParallelPlan.from_legacy("4,2", cfg=cfg, opt_shard=mode) \
                .resolve(cfg, global_batch=8)
            state = init_state(jax.random.PRNGKey(0), cfg, tc, plan=plan)
            fn = make_train_step(cfg, ParallelConfig(opt_overlap=overlap),
                                 tc, plan=plan)
            losses = []
            for b in batches:
                state, m = fn(state, b)
                losses.append(float(m["loss"]))
            return state, losses

        for mode in ("epso", "so"):
            ref_state, ref_losses = run(mode, "off")
            for impl in ("ring", "xla"):
                st_, ls = run(mode, impl)
                assert np.allclose(ref_losses, ls, rtol=1e-6), \\
                    (mode, impl, ref_losses, ls)
                worst = 0.0
                for a, b in zip(jax.tree.leaves(ref_state.params),
                                jax.tree.leaves(st_.params)):
                    d = np.abs(np.asarray(a, np.float64)
                               - np.asarray(b, np.float64)).max()
                    worst = max(worst, float(d))
                assert worst <= 1e-6, (mode, impl, worst)
                print(f"PARITY {mode} {impl} maxdelta={worst:.2e}")
        print("OVERLAP-GOLDEN-OK")
    """, timeout=1800)
    assert "OVERLAP-GOLDEN-OK" in out


@pytest.mark.distributed
@pytest.mark.slow
def test_overlap_composes_with_shardmap_pp_mesh8(mesh8):
    """The overlap composes with the shard_map-per-stage pipeline executor
    on the (data=2, pp=2, ep=2) mesh: overlap on vs off gives bit-equal
    losses (identical forward) and ~1 ulp params, through the full
    ParallelPlan path (``overlap=`` plan token included)."""
    out = mesh8("""
        import jax, numpy as np
        from repro.configs import TrainConfig, get_config, reduced
        from repro.parallel.plan import ParallelPlan
        from repro.train import init_state, make_train_step

        cfg = reduced(get_config("mula-7b-a1b"), layers=2, d_model=64)
        tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                         grad_reduce_dtype="float32", lr_peak=1e-3,
                         lr_min=1e-4, warmup_steps=2, total_steps=10,
                         seq_len=32, global_batch=8)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

        outs = {}
        impls = {}
        for overlap in ("off", "ring"):
            plan = ParallelPlan.parse(
                f"dp=2,pp=2,ep=2,opt=epso,impl=shardmap,mb=4,"
                f"overlap={overlap}").resolve(cfg, global_batch=8)
            state = init_state(jax.random.PRNGKey(0), cfg, tc, plan=plan)
            # parallel=None: the plan's overlap= token drives the step
            fn = make_train_step(cfg, None, tc, plan=plan)
            impls[overlap] = fn.opt_overlap_impl
            losses = []
            for _ in range(3):
                state, m = fn(state, batch)
                losses.append(float(m["loss"]))
            outs[overlap] = (state, losses)
        # both legs must have built what they asked for, or the parity
        # comparison below compares a path against itself
        assert impls == {"off": "off", "ring": "ring"}, impls
        (s0, l0), (s1, l1) = outs["off"], outs["ring"]
        assert l0 == l1, (l0, l1)
        worst = 0.0
        for a, b in zip(jax.tree.leaves(s0.params),
                        jax.tree.leaves(s1.params)):
            d = np.abs(np.asarray(a, np.float64)
                       - np.asarray(b, np.float64)).max()
            worst = max(worst, float(d))
        assert worst <= 1e-6, worst
        print("PP-OVERLAP-COMPOSE-OK maxdelta=%.2e" % worst)
    """, timeout=1800)
    assert "PP-OVERLAP-COMPOSE-OK" in out
