"""ParallelPlan: spec round-trip, validation, kernel-plan scoping,
checkpoint plan metadata, and the golden legacy-vs-plan parity +
expert-TP (dedicated ep x tp axes) mesh tests."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, MoEConfig
from repro.parallel.plan import (KernelPlan, ParallelPlan, ResolvedPlan,
                                 current_kernel_plan, use_kernel_plan)


def moe_cfg(E=4, f=32, name="t-moe"):
    return ModelConfig(name=name, arch_type="moe", num_layers=2, d_model=64,
                       num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64,
                       moe=MoEConfig(num_experts=E, experts_per_token=2,
                                     d_ff_expert=f, moe_impl="fsmoe"))


def dense_cfg(d_ff=128):
    return ModelConfig(name="t-dense", arch_type="dense", num_layers=2,
                       d_model=64, num_heads=2, num_kv_heads=2, d_ff=d_ff,
                       vocab_size=64)


# ---------------------------------------------------------------------------
# parse / str round-trip
# ---------------------------------------------------------------------------

def test_parse_basic():
    p = ParallelPlan.parse("dp=2,pp=2,ep=2")
    assert (p.dp, p.pp, p.ep, p.tp, p.pod) == (2, 2, 2, 1, 1)
    assert p.num_devices == 8
    assert p.mesh_axes() == (("data", 2), ("pp", 2), ("ep", 2))
    # options ride along in the same spec
    q = ParallelPlan.parse("dp=2,ep=2,tp=2,opt=epso,schedule=gpipe,mb=4,fsdp")
    assert q.opt_shard == "epso" and q.pp_schedule == "gpipe"
    assert q.microbatches == 4 and q.fsdp


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.integers(1, 4), st.integers(1, 4),
       st.integers(1, 4), st.integers(1, 2),
       st.sampled_from(["none", "so", "epso"]),
       st.sampled_from(["gpipe", "1f1b"]),
       st.sampled_from(["shardmap", "masked"]),
       st.integers(1, 8), st.booleans(),
       st.sampled_from([None, "capacity", "dropless"]))
def test_parse_str_roundtrip(dp, pp, ep, tp, pod, opt, sched, impl, mb,
                             fsdp, moe):
    p = ParallelPlan(dp=dp, pp=pp, ep=ep, tp=tp, pod=pod, opt_shard=opt,
                     pp_schedule=sched, pp_impl=impl, microbatches=mb,
                     fsdp=fsdp, moe_dispatch=moe)
    assert ParallelPlan.parse(str(p)) == p


def test_parse_moe_dispatch_option():
    p = ParallelPlan.parse("dp=2,ep=2,moe=dropless")
    assert p.moe_dispatch == "dropless"
    assert "moe=dropless" in str(p)
    assert ParallelPlan.parse("dp=2").moe_dispatch is None   # defers to cfg
    with pytest.raises(ValueError, match="moe_dispatch"):
        ParallelPlan.parse("dp=2,moe=sometimes")
    # the plan's ParallelConfig carries the pinned mode to make_train_step
    rp = ResolvedPlan(plan=ParallelPlan.parse("dp=2,moe=dropless"))
    assert rp.parallel_config().moe_dispatch == "dropless"
    assert ResolvedPlan(
        plan=ParallelPlan.parse("dp=2")).parallel_config().moe_dispatch is None


def test_plan_apply_to_model():
    plan = ParallelPlan.parse("dp=2,ep=2,moe=dropless")
    cfg = moe_cfg(E=4)
    assert cfg.moe.dispatch == "capacity"
    cfg2 = plan.apply_to_model(cfg)
    assert cfg2.moe.dispatch == "dropless"
    assert cfg.moe.dispatch == "capacity"          # original untouched
    # nothing pinned, or no MoE block: config passes through unchanged
    assert ParallelPlan.parse("dp=2").apply_to_model(cfg) is cfg
    dense = dense_cfg()
    assert plan.apply_to_model(dense) is dense


def test_parse_errors_are_descriptive():
    with pytest.raises(ValueError, match="unknown role 'qq'"):
        ParallelPlan.parse("dp=2,qq=3")
    with pytest.raises(ValueError, match="sizes must be >= 1"):
        ParallelPlan.parse("dp=0")
    with pytest.raises(ValueError, match="must be an integer"):
        ParallelPlan.parse("dp=x")
    with pytest.raises(ValueError, match="empty parallel spec"):
        ParallelPlan.parse("  ")
    with pytest.raises(ValueError, match="opt_shard"):
        ParallelPlan.parse("dp=2,opt=zorp")
    with pytest.raises(ValueError, match="pp_schedule"):
        ParallelPlan.parse("dp=2,schedule=zigzag")
    with pytest.raises(ValueError, match="pp_impl"):
        ParallelPlan.parse("dp=2,pp=2,impl=telepathy")
    with pytest.raises(ValueError, match="duplicate 'dp'"):
        ParallelPlan.parse("dp=2,ep=4,dp=8")   # typo'd spec, never last-wins


def test_parse_rebalance_token():
    p = ParallelPlan.parse("dp=2,ep=2,opt=epso,rebalance=50:1.25")
    assert p.rebalance == "50:1.25"
    assert p.rebalance_params() == (50, 1.25)
    assert "rebalance=50:1.25" in str(p)
    assert ParallelPlan.parse(str(p)) == p
    # off / absent both mean 'no policy'
    assert ParallelPlan.parse("dp=2,ep=2,rebalance=off").rebalance_params() \
        is None
    assert ParallelPlan.parse("dp=2,ep=2").rebalance_params() is None
    with pytest.raises(ValueError, match="interval"):
        ParallelPlan.parse("dp=2,ep=2,rebalance=0:1.25")
    with pytest.raises(ValueError, match="threshold"):
        ParallelPlan.parse("dp=2,ep=2,rebalance=50:0.5")
    with pytest.raises(ValueError, match="rebalance="):
        ParallelPlan.parse("dp=2,ep=2,rebalance=always")


def test_rebalance_contracts_and_validation():
    # the plan declares the placement contract only when the policy is live
    p = ParallelPlan.parse("dp=2,ep=2,opt=epso,rebalance=50:1.25")
    assert "placement-consistency" in p.contracts()
    assert "placement-consistency" not in \
        ParallelPlan.parse("dp=2,ep=2,opt=epso").contracts()
    # rebalancing permutes expert stacks: dense models have none
    with pytest.raises(ValueError, match="no experts"):
        ParallelPlan.parse("dp=2,rebalance=50:1.25").validate_model(
            dense_cfg())
    # pp>1 is explicitly unimplemented (stage-sharded layer stacks)
    with pytest.raises(NotImplementedError, match="pipeline"):
        ParallelPlan.parse("dp=2,pp=2,ep=2,rebalance=50:1.25") \
            .validate_model(moe_cfg(E=4))
    ParallelPlan.parse("dp=2,ep=2,rebalance=50:1.25").validate_model(
        moe_cfg(E=4))


def test_validate_model_divisibility():
    # ep on a dense model
    with pytest.raises(ValueError, match="has no experts"):
        ParallelPlan(ep=2).validate_model(dense_cfg())
    # ep not dividing num_experts
    with pytest.raises(ValueError, match="does not divide .* 4 experts"):
        ParallelPlan(ep=3).validate_model(moe_cfg(E=4))
    # tp not dividing the experts' d_ff (the ep x tp contract)
    with pytest.raises(ValueError, match="expert d_ff=33"):
        ParallelPlan(ep=2, tp=2).validate_model(moe_cfg(E=4, f=33))
    # tp not dividing a dense d_ff
    with pytest.raises(ValueError, match="d_ff=130"):
        ParallelPlan(tp=4).validate_model(dense_cfg(d_ff=130))
    # valid combinations pass
    ParallelPlan(ep=2, tp=2).validate_model(moe_cfg(E=4, f=32))
    ParallelPlan(pp=2).validate_model(dense_cfg())
    with pytest.raises(ValueError, match="pipeline stage"):
        ParallelPlan(pp=3).validate_model(dense_cfg())


def test_from_legacy_role_inference():
    # MoE + divisible expert count -> the model axis becomes ep
    p = ParallelPlan.from_legacy("4,2", cfg=moe_cfg(E=4), opt_shard="epso")
    assert (p.dp, p.ep, p.tp, p.opt_shard) == (4, 2, 1, "epso")
    # MoE + non-divisible expert count -> the old 'etp' fallback = tp
    p = ParallelPlan.from_legacy("2,4", cfg=moe_cfg(E=6))
    assert (p.dp, p.ep, p.tp) == (2, 1, 4)
    # dense -> tp; 3-dim spec carries pp
    p = ParallelPlan.from_legacy("2,2,2", cfg=dense_cfg())
    assert (p.dp, p.pp, p.ep, p.tp) == (2, 2, 1, 2)
    # and the same 3-dim spec on a MoE maps model -> ep
    p = ParallelPlan.from_legacy("2,2,2", cfg=moe_cfg(E=4))
    assert (p.dp, p.pp, p.ep, p.tp) == (2, 2, 2, 1)


def test_single_device_plan_resolves_to_no_mesh():
    plan = ParallelPlan().resolve(moe_cfg())
    assert plan.mesh is None and plan.rules is None
    assert plan.parallel_config().pp_stages == 1


# ---------------------------------------------------------------------------
# KernelPlan scoping (the KERNEL_CONFIG / ATTN_IMPL replacement)
# ---------------------------------------------------------------------------

def test_kernel_plan_scoping_restores():
    from repro.kernels import ops
    base = ops.gmm_align()
    with use_kernel_plan(dataclasses.replace(current_kernel_plan(),
                                             tile_m=8)):
        assert ops.gmm_align() == 8
        # nested scopes stack
        with use_kernel_plan(dataclasses.replace(current_kernel_plan(),
                                                 tile_m=16)):
            assert ops.gmm_align() == 16
        assert ops.gmm_align() == 8
    assert ops.gmm_align() == base


def test_retired_aliases_are_tombstoned():
    """The PR 4 compatibility aliases are deleted, not just deprecated:
    the symbols no longer exist (lint rule SL004 forbids them repo-wide)."""
    from repro.kernels import ops
    from repro.models import layers as L
    # getattr with string names: SL004 forbids the bare identifiers even here
    assert not hasattr(ops, "KERNEL_CONFIG")
    with pytest.raises(AttributeError):
        getattr(L, "ATTN_IMPL")
    # the replacement path still answers the same question
    assert L._attn_impl() == current_kernel_plan().attn_impl == "blockwise"
    with use_kernel_plan(dataclasses.replace(current_kernel_plan(),
                                             attn_impl="pallas")):
        assert L._attn_impl() == "pallas"
    assert L._attn_impl() == "blockwise"


def test_default_kernel_plan_swap_and_scope_precedence():
    """set_default_kernel_plan replaces the process default; a scoped
    use_kernel_plan always outranks it and restores on exit."""
    from repro.kernels import ops
    from repro.parallel.plan import (default_kernel_plan,
                                     set_default_kernel_plan)
    old = default_kernel_plan()
    try:
        set_default_kernel_plan(dataclasses.replace(old, tile_m=8))
        assert ops.gmm_align() == 8 == current_kernel_plan().tile_m
        with use_kernel_plan(dataclasses.replace(current_kernel_plan(),
                                                 tile_m=16)):
            assert ops.gmm_align() == 16
        assert ops.gmm_align() == 8
    finally:
        set_default_kernel_plan(old)
    assert ops.gmm_align() == old.tile_m


def test_kernel_plan_validation():
    with pytest.raises(ValueError, match="backend"):
        KernelPlan(backend="cuda")
    with pytest.raises(ValueError, match="attn_impl"):
        KernelPlan(attn_impl="vanilla")


def test_kernel_plan_backend_drives_moe_stage_backend():
    """KernelPlan.backend retargets the MoE stage-4/5 kernels: a
    'pallas'-backend plan produces the same numbers as the xla reference
    through sparse_moe_block (dense-capacity path, dropless regime)."""
    import jax
    import numpy as np
    from repro.core import moe as M

    cfg = moe_cfg(E=4, f=32)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=2.0))     # dropless: backends must agree
    assert M.stage45_backend(cfg.moe) == cfg.moe.kernel_backend  # 'ref' plan
    p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
    ref, _, _, _ = M.sparse_moe_block(p, x, cfg)
    with use_kernel_plan(KernelPlan(backend="pallas", tile_m=8)):
        assert M.stage45_backend(cfg.moe) == "pallas"
        out, _, _, _ = M.sparse_moe_block(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


# ---------------------------------------------------------------------------
# Checkpointer plan metadata (the silent-reshard bugfix)
# ---------------------------------------------------------------------------

def _resolved(spec: str) -> ResolvedPlan:
    # layout metadata only — no mesh needed off-device
    return ResolvedPlan(plan=ParallelPlan.parse(spec))


def test_checkpointer_plan_mismatch_errors(tmp_path):
    import jax.numpy as jnp
    from repro.checkpoint import Checkpointer

    state = {"w": jnp.arange(4, dtype=jnp.float32)}
    ck = Checkpointer(str(tmp_path), interval=1,
                      plan=_resolved("dp=2,ep=2,opt=epso"))
    ck.save(state, 3)

    # same layout -> restores fine
    same = Checkpointer(str(tmp_path), interval=1,
                        plan=_resolved("dp=2,ep=2,opt=epso"))
    restored, step = same.restore(state)
    assert step == 3 and np.array_equal(restored["w"], state["w"])

    # different axis layout -> hard error instead of silent reshard
    other = Checkpointer(str(tmp_path), interval=1,
                         plan=_resolved("dp=4,opt=so"))
    with pytest.raises(ValueError, match="refusing to silently reshard"):
        other.restore(state)

    # explicit re-plan opt-in
    replan = Checkpointer(str(tmp_path), interval=1,
                          plan=_resolved("dp=4,opt=so"),
                          on_plan_mismatch="reshard")
    restored, step = replan.restore(state)
    assert step == 3 and np.array_equal(restored["w"], state["w"])

    # legacy caller (no plan) keeps working against a plan-stamped ckpt
    legacy = Checkpointer(str(tmp_path), interval=1)
    restored, step = legacy.restore(state)
    assert step == 3


# ---------------------------------------------------------------------------
# mesh tests: golden parity + the dedicated ep x tp axis pair
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.slow
def test_plan_matches_legacy_mesh_bit_identical(mesh8, tmp_path):
    """Golden parity: a plan-built (2,2,2) EPSO+PP run is bit-identical
    (loss history + final checkpointed state) to the legacy
    --mesh 2,2,2 --opt-shard epso path."""
    out = mesh8(f"""
        import json, os
        import numpy as np
        from repro.launch.train import run

        base = {str(tmp_path)!r}
        KW = dict(steps=8, batch=8, seq=32, d_model=64, ckpt_interval=5,
                  opt_shard="epso", log_every=100)
        legacy = run("mula-7b-a1b", out=f"{{base}}/legacy", mesh="2,2,2",
                     **KW)
        plan = run("mula-7b-a1b", out=f"{{base}}/plan",
                   parallel="dp=2,pp=2,ep=2", **KW)
        la = [h["loss"] for h in legacy]
        lb = [h["loss"] for h in plan]
        assert la == lb, (la, lb)

        def newest(d, want):
            for slot in ("ckpt-1", "ckpt-2"):
                man = os.path.join(d, "ckpt", slot, "MANIFEST.json")
                if os.path.exists(man):
                    with open(man) as f:
                        m = json.load(f)
                    if m.get("valid") and int(m["step"]) == want:
                        return (dict(np.load(os.path.join(d, "ckpt", slot,
                                                          "state.npz"))), m)
            raise AssertionError(f"no valid ckpt @ {{want}} in {{d}}")

        sa, ma = newest(f"{{base}}/legacy", 5)
        sb, mb = newest(f"{{base}}/plan", 5)
        assert sorted(sa) == sorted(sb)
        for k in sa:
            assert sa[k].dtype == sb[k].dtype, k
            assert np.array_equal(sa[k], sb[k]), k
        # both manifests carry the plan layout (the legacy path goes
        # through the from_legacy shim, so it records the same axes)
        assert ma["plan"]["layout"] == mb["plan"]["layout"], (ma, mb)
        assert ma["plan"]["layout"]["axes"] == [["data", 2], ["pp", 2],
                                               ["ep", 2]]
        print("PARITY-OK")
    """, timeout=1800)
    assert "PARITY-OK" in out


@pytest.mark.distributed
@pytest.mark.slow
def test_ep_tp_axis_pair_through_sparse_moe_block(mesh8):
    """Expert-TP: a dedicated ep=2 x tp=2 axis pair (inexpressible on the
    legacy shared 'model' axis) through sparse_moe_block — forward and
    gradients match the naive single-device reference."""
    out = mesh8("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType
        from repro.configs.base import ModelConfig, MoEConfig
        from repro.core import moe as M
        mesh = jax.make_mesh((2, 2, 2), ("data", "ep", "tp"),
                             axis_types=(AxisType.Auto,)*3)
        cfg = ModelConfig(name="t", arch_type="moe", num_layers=1, d_model=32,
                          num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64,
                          moe=MoEConfig(num_experts=4, experts_per_token=2,
                                        d_ff_expert=16, capacity_factor=2.0,
                                        moe_impl="fsmoe"))
        p = M.init_moe_block(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        ref, _ = M.moe_naive(p, x, cfg.moe)
        pspec = {"router": P(), "gate": P("ep", None, "tp"),
                 "up": P("ep", None, "tp"), "down": P("ep", "tp", None)}
        ps = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                          p, pspec)
        xs = jax.device_put(x, NamedSharding(mesh, P(("data", "ep"), None)))
        # a tp_axis that is not a mesh axis fails loudly, never silently
        try:
            M.moe_fsmoe_ep(p, x, cfg.moe, mesh=mesh, ep_axis="ep",
                           tp_axis="nope")
            raise AssertionError("expected ValueError for bad tp_axis")
        except ValueError as e:
            assert "not a mesh axis" in str(e)
        def f(p, x):
            out, aux, z, stats = M.sparse_moe_block(
                p, x.reshape(4, 16, 32), cfg, mesh=mesh, ep_axis="ep",
                tp_axis="tp", batch_axes=("data",))
            return out.reshape(64, 32)
        out = jax.jit(f)(ps, xs)
        assert np.allclose(ref, out, atol=1e-4), "forward mismatch"
        g1 = jax.jit(jax.grad(lambda p, x: (f(p, x)**2).sum()))(ps, xs)
        g2 = jax.grad(lambda p: (M.moe_naive(p, x, cfg.moe)[0]**2).sum())(p)
        for k in ("router", "gate", "up", "down"):
            assert np.allclose(g1[k], g2[k], atol=1e-3), k
        print("EP-TP-OK")
    """)
    assert "EP-TP-OK" in out


@pytest.mark.distributed
@pytest.mark.slow
def test_ep_tp_plan_trains(mesh8, tmp_path):
    """A dp=2,ep=2,tp=2 plan — EP and TP as distinct axes — trains a MoE
    config for 10 steps with finite, decreasing loss."""
    out = mesh8(f"""
        import numpy as np
        from repro.launch.train import run
        r = run("mula-7b-a1b", steps=10, batch=8, seq=32, d_model=64,
                out={str(tmp_path)!r} + "/eptp", parallel="dp=2,ep=2,tp=2",
                ckpt_interval=50, log_every=100)
        losses = [h["loss"] for h in r]
        assert len(losses) == 10
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses
        print("EP-TP-TRAIN-OK")
    """, timeout=1800)
    assert "EP-TP-TRAIN-OK" in out


@pytest.mark.distributed
@pytest.mark.slow
def test_plan_resolution_on_mesh(mesh8):
    """resolve() builds the mesh + rules once with dedicated axes; the
    dry-run description renders placement without allocating."""
    out = mesh8("""
        from repro.configs import get_config, reduced
        from repro.parallel.plan import ParallelPlan
        cfg = reduced(get_config("mula-7b-a1b"), d_model=64)
        plan = ParallelPlan.parse("dp=2,ep=2,tp=2,opt=epso").resolve(
            cfg, global_batch=8)
        assert tuple(plan.mesh.shape.keys()) == ("data", "ep", "tp")
        assert plan.rules.ep_axis == "ep" and plan.rules.tp_axis == "tp"
        assert "ep" in plan.rules.batch_axes
        text = plan.describe(cfg)
        assert "moe" in text and "ep" in text and "bytes/device" in text
        print("RESOLVE-OK")
    """)
    assert "RESOLVE-OK" in out
