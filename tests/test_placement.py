"""Telemetry-driven expert placement + live EP rebalancing
(parallel/placement.py, ISSUE 10 tentpole).

Host-side units (permutation algebra, greedy LPT, windowed controller),
single-device numerics preservation (a placement is pure data movement:
losses and global-id telemetry are bit-identical under a permuted expert
stack), manifest/checkpoint round-trips, and the mesh8 goldens — a forced
rebalance event mid-run leaves the loss history bit-identical to the
static run, and a mid-schedule resume across the event stays bit-exact.
"""
import json
import os

import numpy as np
import pytest

from repro.parallel.placement import (ExpertPlacement, RebalanceController,
                                      greedy_perm, imbalance, is_expert_stack,
                                      permute_expert_tree, rank_loads)


# ---------------------------------------------------------------------------
# ExpertPlacement: permutation algebra + manifest round-trip
# ---------------------------------------------------------------------------

def test_identity_and_broadcast():
    pl = ExpertPlacement.identity(3, 4)
    assert pl.is_identity
    assert pl.perm == (tuple(range(4)),) * 3
    b = ExpertPlacement.broadcast((2, 3, 0, 1), 3)
    assert not b.is_identity
    assert b.num_layers == 3 and b.num_experts == 4
    assert b.perm == ((2, 3, 0, 1),) * 3


def test_inverse_is_argsort_round_trip():
    pl = ExpertPlacement(2, 4, ((2, 0, 3, 1), (1, 3, 0, 2)))
    fwd, inv = pl.perm_array(), pl.inverse_array()
    assert fwd.dtype == np.int32 and inv.dtype == np.int32
    for l in range(2):
        # inv[global id] = position holding it: fwd[inv[g]] == g
        assert list(fwd[l][inv[l]]) == [0, 1, 2, 3]
        assert list(inv[l][fwd[l]]) == [0, 1, 2, 3]


def test_relative_to_moves_live_arrays():
    """rel = cur.relative_to(new) must satisfy W_new[pos] = W_live[rel[pos]]
    where W_live[p] = W_global[cur.perm[p]]."""
    cur = ExpertPlacement.broadcast((2, 0, 3, 1), 2)
    new = ExpertPlacement.broadcast((3, 1, 2, 0), 2)
    rel = cur.relative_to(new)
    w_global = np.arange(4) * 10
    w_live = w_global[cur.perm_array()[0]]
    w_new = w_live[rel[0]]
    assert list(w_new) == list(w_global[new.perm_array()[0]])
    # identity -> new is just new's forward row
    ident = ExpertPlacement.identity(2, 4)
    assert np.array_equal(ident.relative_to(new), new.perm_array())
    # round trip: moving there and back is the identity gather
    back = new.relative_to(cur)
    assert np.array_equal(rel[0][back[0]], np.arange(4))


def test_manifest_round_trip_and_none():
    pl = ExpertPlacement(2, 4, ((2, 0, 3, 1), (0, 1, 2, 3)))
    assert ExpertPlacement.from_manifest(pl.to_manifest()) == pl
    # JSON-clean (what rides in the checkpoint MANIFEST)
    assert ExpertPlacement.from_manifest(
        json.loads(json.dumps(pl.to_manifest()))) == pl
    assert ExpertPlacement.from_manifest(None) is None


def test_validation_errors():
    with pytest.raises(ValueError, match="rows"):
        ExpertPlacement(3, 4, ((0, 1, 2, 3),) * 2)
    with pytest.raises(ValueError, match="not a permutation"):
        ExpertPlacement(1, 4, ((0, 1, 2, 2),))
    with pytest.raises(ValueError, match="shape mismatch"):
        ExpertPlacement.identity(2, 4).relative_to(
            ExpertPlacement.identity(2, 8))


# ---------------------------------------------------------------------------
# load metrics + greedy LPT
# ---------------------------------------------------------------------------

def test_rank_loads_and_imbalance():
    counts = [100, 50, 10, 40]            # global-id space
    assert list(rank_loads(counts, (0, 1, 2, 3), 2)) == [150, 50]
    assert imbalance(counts, (0, 1, 2, 3), 2) == pytest.approx(1.5)
    # pairing hot with cold balances: ranks (100+40, 50+10)=(140,60)? no —
    # (0,3 | 1,2) -> (140, 60); (0,2 | 1,3) -> (110, 90)
    assert imbalance(counts, (0, 2, 1, 3), 2) == pytest.approx(1.1)
    assert imbalance(np.zeros(4), (0, 1, 2, 3), 2) == 1.0


def test_greedy_perm_balances_skew():
    rng = np.random.default_rng(0)
    for ep in (2, 4):
        counts = rng.zipf(1.4, size=8).astype(np.float64)
        row = greedy_perm(counts, ep)
        assert sorted(row) == list(range(8))
        assert imbalance(counts, row, ep) <= imbalance(
            counts, tuple(range(8)), ep) + 1e-12
        assert row == greedy_perm(counts, ep)     # deterministic
    # textbook LPT: hottest goes to rank 0, next to rank 1, ...
    assert greedy_perm([100, 50, 10, 40], 2) == (0, 2, 1, 3)
    with pytest.raises(ValueError, match="does not divide"):
        greedy_perm([1.0, 2.0, 3.0], 2)
    with pytest.raises(ValueError, match="does not divide"):
        rank_loads([1.0, 2.0, 3.0], (0, 1, 2), 2)


def test_is_expert_stack_selects_routed_stacks_only():
    L, E = 2, 4
    assert is_expert_stack("layers/moe/gate", (L, E, 8, 16), L, E)
    assert is_expert_stack("layers/moe/down", (L, E, 16, 8), L, E)
    assert not is_expert_stack("layers/moe/router", (L, 8, E), L, E)
    assert not is_expert_stack("layers/moe/shared/gate", (L, E, 8, 16), L, E)
    assert not is_expert_stack("layers/attn/wq", (L, E, 8, 16), L, E)
    assert not is_expert_stack("layers/moe/gate", (L, E), L, E)  # no tail dim


# ---------------------------------------------------------------------------
# RebalanceController: windowed host loop
# ---------------------------------------------------------------------------

def test_controller_windowing_and_threshold():
    c = RebalanceController(num_layers=2, num_experts=4, ep=2,
                            interval=3, threshold=1.2)
    # balanced counts: observe returns the live per-step imbalance
    assert c.observe([10, 10, 10, 10]) == pytest.approx(1.0)
    assert not c.window_full()
    c.observe([10, 10, 10, 10])
    c.observe([10, 10, 10, 10])
    assert c.window_full()
    assert c.propose() is None                 # below threshold: no event
    assert not c.window_full()                 # propose resets the window
    assert c.rebalances == 0
    # skewed window above threshold: adopts the greedy placement
    for _ in range(3):
        assert c.observe([100, 50, 10, 40]) == pytest.approx(1.5)
    new = c.propose()
    assert new is not None and new.perm[0] == (0, 2, 1, 3)
    assert c.placement == new and c.rebalances == 1
    # same skew again: greedy reproposes the already-live row -> no event
    for _ in range(3):
        c.observe([100, 50, 10, 40])
    assert c.propose() is None and c.rebalances == 1


def test_controller_force_and_reset():
    c = RebalanceController(num_layers=1, num_experts=4, ep=2,
                            interval=100, threshold=10.0)
    c.observe([100, 50, 10, 40])
    # forced mid-window, threshold never reached: still adopts
    new = c.propose(force=True)
    assert new is not None and c.rebalances == 1
    assert c.steps_in_window == 0
    # empty window: force is a no-op
    assert c.propose(force=True) is None
    c.observe([1, 1, 1, 1])
    c.reset_window()                           # relaunch rollback path
    assert c.steps_in_window == 0 and c.window.sum() == 0
    assert c.propose(force=True) is None       # nothing observed
    with pytest.raises(ValueError, match="interval"):
        RebalanceController(num_layers=1, num_experts=4, ep=2,
                            interval=0, threshold=1.5)
    with pytest.raises(ValueError, match="threshold"):
        RebalanceController(num_layers=1, num_experts=4, ep=2,
                            interval=5, threshold=0.5)


# ---------------------------------------------------------------------------
# numerics preservation, single device: a placement is pure data movement
# ---------------------------------------------------------------------------

def test_placed_train_step_bit_identical_and_counts_conserved():
    """Permute the expert stacks (params AND optimizer state) to a
    non-identity placement, train with the placement threaded through the
    plan: losses and the global-id ``moe_counts`` telemetry are bit-equal
    to the identity run, and un-permuting the trained stacks recovers the
    identity run's params bitwise (top_k=2: see placement.py docstring)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import (ParallelConfig, TrainConfig, get_config,
                               reduced)
    from repro.parallel.placement import apply_placement
    from repro.parallel.plan import ParallelPlan
    from repro.train import init_state, make_train_step

    cfg = reduced(get_config("mula-7b-a1b"), d_model=32)
    L, E = cfg.num_layers, cfg.moe.num_experts
    assert cfg.moe.experts_per_token <= 2     # bit-identity precondition
    tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                     grad_reduce_dtype="float32", lr_peak=1e-3, lr_min=1e-4,
                     warmup_steps=2, total_steps=4, seq_len=16,
                     global_batch=4)
    base = ParallelPlan().resolve(cfg, global_batch=4)   # meshless
    assert base.mesh is None
    ident = ExpertPlacement.identity(L, E)
    placed = ExpertPlacement.broadcast(tuple(reversed(range(E))), L)

    batches = []
    for s in range(4):
        t = jax.random.randint(jax.random.PRNGKey(100 + s), (4, 17), 0,
                               cfg.vocab_size)
        batches.append({"tokens": t[:, :-1], "labels": t[:, 1:]})

    def train(plan, state):
        fn = make_train_step(cfg, ParallelConfig(), tc, plan=plan)
        losses, counts = [], []
        for b in batches:
            state, m = fn(state, b)
            losses.append(float(m["loss"]))
            counts.append(np.asarray(m["moe_counts"]))
        return state, losses, counts

    state0 = init_state(jax.random.PRNGKey(0), cfg, tc, plan=base)
    sa, la, ca = train(base, state0)

    state0 = init_state(jax.random.PRNGKey(0), cfg, tc, plan=base)
    state_p = apply_placement(state0, ident, placed, L, E)
    # the router is never permuted; the expert stacks are
    assert np.array_equal(np.asarray(state_p.params["layers"]["moe"]["router"]),
                          np.asarray(state0.params["layers"]["moe"]["router"]))
    rel = ident.relative_to(placed)
    g0 = np.asarray(state0.params["layers"]["moe"]["gate"])
    gp = np.asarray(state_p.params["layers"]["moe"]["gate"])
    for l in range(L):
        assert np.array_equal(gp[l], g0[l][rel[l]])
    sb, lb, cb = train(base.with_placement(placed), state_p)

    assert la == lb, (la, lb)                  # bit-identical losses
    for a, b in zip(ca, cb):                   # telemetry in global-id space
        assert np.array_equal(a, b)
    # moving the trained state back to identity recovers the base run bitwise
    sb_back = apply_placement(sb, placed, ident, L, E)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(sa.params),
            jax.tree_util.tree_leaves_with_path(sb_back.params)):
        assert jax.tree_util.keystr(pa) == jax.tree_util.keystr(pb)
        assert np.array_equal(np.asarray(a), np.asarray(b)), pa
    for a, b in zip(jax.tree_util.tree_leaves(sa.opt),
                    jax.tree_util.tree_leaves(sb_back.opt)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_update_plan_invariant_under_placement():
    """optim/epso.py claims the bucket schedule can't see a placement (it
    reads only shapes and specs) — pin it: the plan computed from permuted
    shapes is identical."""
    import jax
    from repro.compat import AxisType
    from jax.sharding import AbstractMesh
    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.optim.epso import plan_update_buckets
    from repro.parallel.sharding import make_rules

    cfg = reduced(get_config("mula-7b-a1b"), d_model=64)
    L, E = cfg.num_layers, cfg.moe.num_experts
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = AbstractMesh((2, 4), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)
    rules = make_rules(cfg, mesh, kind="train", global_batch=8)
    before = plan_update_buckets(params, rules, "epso")
    rel = ExpertPlacement.identity(L, E).relative_to(
        ExpertPlacement.broadcast(tuple(reversed(range(E))), L))
    permuted = permute_expert_tree(params, rel, L, E)
    assert jax.tree.map(lambda a: a.shape, permuted) \
        == jax.tree.map(lambda a: a.shape, params)
    assert plan_update_buckets(permuted, rules, "epso") == before


# ---------------------------------------------------------------------------
# checkpoint: placement rides the MANIFEST
# ---------------------------------------------------------------------------

def test_checkpointer_placement_round_trip(tmp_path):
    import jax.numpy as jnp
    from repro.checkpoint.checkpointer import Checkpointer

    state = {"w": jnp.arange(6.0).reshape(2, 3), "step": jnp.int32(3)}
    pl = ExpertPlacement.broadcast((2, 0, 3, 1), 2)
    ck = Checkpointer(str(tmp_path / "ck"), interval=1, placement=pl)
    ck.save(state, 3)
    ck2 = Checkpointer(str(tmp_path / "ck"), interval=1)
    restored, step = ck2.restore(state)
    assert step == 3
    assert ck2.restored_placement == pl
    assert np.array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    # identity-placement runs write no placement key and restore None
    ck3 = Checkpointer(str(tmp_path / "ck0"), interval=1)
    ck3.save(state, 1)
    ck3.restore(state)
    assert ck3.restored_placement is None


# ---------------------------------------------------------------------------
# KV pool bookkeeping (satellite: O(1) free + double-free guard)
# ---------------------------------------------------------------------------

def test_kv_pool_free_is_guarded_and_constant_time():
    from repro.configs import get_config, reduced
    from repro.serve.kv_pool import SlotKVPool

    cfg = reduced(get_config("mula-7b-a1b"), d_model=32)
    pool = SlotKVPool(cfg, 4, 8)
    a, b = pool.alloc(), pool.alloc()
    pool.free(a)
    with pytest.raises(ValueError, match="bad free"):
        pool.free(a)                     # double free
    with pytest.raises(ValueError, match="bad free"):
        pool.free(99)                    # out of range
    # the mirror set stays consistent with the deque through churn
    pool.free(b)
    seen = [pool.alloc() for _ in range(pool.num_free)]
    assert sorted(seen) == sorted(set(seen))
    assert pool.num_free == 0 and pool._free_set == set()
    for s in seen:
        pool.free(s)
    assert pool._free_set == set(pool._free) and pool.num_free == 4


# ---------------------------------------------------------------------------
# mesh8 goldens: forced rebalance event + mid-schedule resume
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.slow
def test_forced_rebalance_bit_identical_losses(mesh8, tmp_path):
    """ISSUE 10 acceptance: on dp=2,ep=2,tp=2 with epso + ring overlap, a
    forced rebalance at step 3 moves the expert stacks and optimizer state
    across EP ranks mid-run — and the loss history stays bit-identical to
    the static run."""
    out = mesh8(f"""
        import json, os
        from repro.launch.train import run

        base = {str(tmp_path)!r}
        KW = dict(batch=8, seq=32, d_model=64, steps=8, ckpt_interval=100,
                  parallel="dp=2,ep=2,tp=2,opt=epso,overlap=ring",
                  log_every=100)

        static = run("mula-7b-a1b", out=f"{{base}}/static", **KW)
        forced = run("mula-7b-a1b", out=f"{{base}}/forced",
                     rebalance_force_at=3, **KW)
        la = [h["loss"] for h in static]
        lb = [h["loss"] for h in forced]
        assert la == lb, (la, lb)
        assert [h["step"] for h in forced] == list(range(8))
        assert forced[3].get("rebalanced") is True, forced[3]
        assert not any(h.get("rebalanced") for h in static)
        with open(f"{{base}}/forced/summary.json") as f:
            s = json.load(f)
        assert s["rebalances"] >= 1, s
        with open(f"{{base}}/static/summary.json") as f:
            s0 = json.load(f)
        assert s0["rebalances"] in (0, None), s0
        print("REBALANCE-GOLDEN-OK")
    """, timeout=1800)
    assert "REBALANCE-GOLDEN-OK" in out


@pytest.mark.distributed
@pytest.mark.slow
def test_rebalance_mid_schedule_resume_bit_identical(mesh8, tmp_path):
    """Resume after the rebalance event: the checkpoint at step 5 holds
    *placed* arrays plus the MANIFEST placement; restoring must rebuild the
    step against that placement and continue bit-identically."""
    out = mesh8(f"""
        import json, os
        import numpy as np
        from repro.launch.train import run

        base = {str(tmp_path)!r}
        KW = dict(batch=8, seq=32, d_model=64, ckpt_interval=5,
                  parallel="dp=2,ep=2,tp=2,opt=epso,overlap=ring",
                  rebalance_force_at=3, log_every=100)

        straight = run("mula-7b-a1b", steps=8, out=f"{{base}}/straight", **KW)
        run("mula-7b-a1b", steps=6, out=f"{{base}}/resumed", **KW)
        resumed = run("mula-7b-a1b", steps=8, out=f"{{base}}/resumed", **KW)
        assert [h["step"] for h in resumed] == [6, 7]
        la = [h["loss"] for h in straight if h["step"] >= 6]
        lb = [h["loss"] for h in resumed]
        assert la == lb, (la, lb)

        # the step-5 checkpoints carry a non-identity manifest placement and
        # identical placed arrays (the event happened before the save)
        def slot5(d):
            for slot in ("ckpt-1", "ckpt-2"):
                man = os.path.join(d, "ckpt", slot, "MANIFEST.json")
                if os.path.exists(man):
                    with open(man) as f:
                        m = json.load(f)
                    if m.get("valid") and int(m["step"]) == 5:
                        return m, dict(np.load(os.path.join(
                            d, "ckpt", slot, "state.npz")))
            raise AssertionError(f"no valid ckpt @ 5 in {{d}}")

        ma, sa = slot5(f"{{base}}/straight")
        mb, sb = slot5(f"{{base}}/resumed")
        assert ma.get("placement") is not None
        assert ma["placement"] == mb["placement"]
        ident = [list(range(ma["placement"]["num_experts"]))] \
            * ma["placement"]["num_layers"]
        assert ma["placement"]["perm"] != ident
        assert sorted(sa) == sorted(sb)
        for k in sa:
            assert np.array_equal(sa[k], sb[k]), k
        print("REBALANCE-RESUME-OK")
    """, timeout=1800)
    assert "REBALANCE-RESUME-OK" in out
