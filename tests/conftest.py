import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS in a separate process; never set device-count flags here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")
for _p in (_SRC, _HERE):
    if _p not in sys.path:
        sys.path.insert(0, _p)

# old-jax shims (jax.sharding.AxisType / AbstractMesh signature / make_mesh
# axis_types kwarg) — a no-op on modern jax.
import repro.compat  # noqa: E402,F401

# the suite's property tests use hypothesis; fall back to the deterministic
# sampler stub when the real package isn't installed.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub as _h

    sys.modules.setdefault("hypothesis", _h)
    sys.modules.setdefault("hypothesis.strategies", _h.strategies)

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
