import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS in a separate process; never set device-count flags here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")
for _p in (_SRC, _HERE):
    if _p not in sys.path:
        sys.path.insert(0, _p)

# old-jax shims (jax.sharding.AxisType / AbstractMesh signature / make_mesh
# axis_types kwarg) — a no-op on modern jax.
import repro.compat  # noqa: E402,F401

# the suite's property tests use hypothesis; fall back to the deterministic
# sampler stub when the real package isn't installed.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub as _h

    sys.modules.setdefault("hypothesis", _h)
    sys.modules.setdefault("hypothesis.strategies", _h.strategies)

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# forced-8-device subprocess sessions (the `distributed` marker's substrate):
# the main pytest process keeps its single-device view; mesh tests run their
# snippet in a child process whose backend is forced to 8 CPU host devices.
# ---------------------------------------------------------------------------

_ROOT = os.path.dirname(_HERE)
_N_FORCED = 8
_mesh8_ok = None


def _mesh8_env():
    from repro.launch.mesh import forced_device_env
    env = forced_device_env(_N_FORCED)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _mesh8_available() -> bool:
    global _mesh8_ok
    if _mesh8_ok is None:
        import subprocess
        try:
            r = subprocess.run(
                [sys.executable, "-c",
                 f"import jax; assert len(jax.devices()) == {_N_FORCED}"],
                capture_output=True, env=_mesh8_env(), timeout=300)
            _mesh8_ok = r.returncode == 0
        except Exception:
            _mesh8_ok = False
    return _mesh8_ok


@pytest.fixture(scope="session")
def mesh8():
    """Callable running a python snippet in a subprocess with 8 forced CPU
    host devices; returns its stdout, asserts exit 0, and skips the test
    cleanly when the platform can't force host devices."""
    if not _mesh8_available():
        pytest.skip(f"cannot force {_N_FORCED} CPU host devices")
    import subprocess
    import textwrap

    def run_sub(code: str, timeout: int = 900) -> str:
        r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                           capture_output=True, text=True, env=_mesh8_env(),
                           timeout=timeout)
        assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
        return r.stdout

    return run_sub
