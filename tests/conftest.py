import os

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS in a separate process; never set device-count flags here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
